"""Training launcher: any registered arch (reduced or full), optional mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
      --steps 100
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import Model
from repro.training import adamw, make_train_step
from repro.training import checkpoint as ckpt
from repro.training.data import lm_batches


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg, remat=True)
    params = model.init(jax.random.PRNGKey(0))
    print(f"training {cfg.name}: "
          f"{sum(x.size for x in jax.tree.leaves(params)) / 1e6:.1f}M params")
    opt = adamw(lr=args.lr, moment_dtype=jnp.bfloat16)
    state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
    data = lm_batches(cfg, args.batch, args.seq)
    t0 = time.time()
    for step in range(1, args.steps + 1):
        params, state, m = step_fn(params, state, next(data))
        if step % args.log_every == 0 or step == 1:
            print(f"step {step:5d} loss={float(m['loss']):.4f} "
                  f"({(time.time() - t0) / step * 1e3:.0f} ms/step)")
    if args.ckpt:
        ckpt.save(args.ckpt, {"params": params, "opt": state}, args.steps)
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
