"""Configuration system: model architecture, input shapes, mesh, runtime.

Every assigned architecture is expressed as a ``ModelConfig``; reduced
variants for CPU smoke tests come from ``ModelConfig.reduced()``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model architecture config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    top_k: int = 0
    n_shared: int = 0             # shared (always-on) experts
    expert_ff: int = 0            # hidden dim of each routed expert
    shared_ff: int = 0            # hidden dim of the shared expert(s)
    first_k_dense: int = 0        # leading dense layers (deepseek-v3 style)
    dense_ff: int = 0             # ff of those leading dense layers
    aux_coef: float = 0.01        # load-balance aux loss coefficient
    capacity_factor: float = 2.0  # EP dispatch capacity slack


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 64               # SSD chunk length
    n_groups: int = 1             # B/C groups (mamba2 "G")


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 6
    n_frames: int = 1500          # stub audio frontend output length
    max_target_positions: int = 448


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    act: str = "silu"             # silu | gelu | relu2
    gated_mlp: bool = True
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    qk_norm: bool = False
    attn_bias: bool = False
    rope: str = "standard"        # standard | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()
    tie_embeddings: bool = False
    window: Optional[int] = None  # sliding-window size (None = full attention)
    n_meta_tokens: int = 0        # hymba learned prefix tokens
    mtp: bool = False             # deepseek multi-token prediction head
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)
    encdec: EncDecConfig = field(default_factory=EncDecConfig)
    source: str = ""              # citation for the config

    # ------------------------------------------------------------------
    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def uses_mla(self) -> bool:
        return self.family == "moe" and self.mla.kv_lora_rank > 0 and \
            self.name.startswith("deepseek")

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm.expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm.head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests.

        2 layers, d_model<=512, <=4 experts, small vocab.
        """
        kw = dict(
            name=self.name + "-reduced",
            n_layers=2,
            d_model=min(self.d_model, 128),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=32 if self.head_dim else 0,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
        )
        if self.family == "moe":
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                expert_ff=min(self.moe.expert_ff, 128),
                shared_ff=min(self.moe.shared_ff, 128) if self.moe.shared_ff else 0,
                first_k_dense=min(self.moe.first_k_dense, 1),
                dense_ff=min(self.moe.dense_ff, 128) if self.moe.dense_ff else 0,
            )
        if self.family in ("ssm", "hybrid"):
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=min(self.ssm.d_state, 16),
                head_dim=16, chunk=16)
        if self.family == "encdec":
            kw["encdec"] = dataclasses.replace(
                self.encdec, n_enc_layers=2, n_frames=16)
        if self.rope == "mrope":
            # keep 3 sections summing to head_dim//2 = 16
            kw["mrope_sections"] = (4, 6, 6)
        if self.n_meta_tokens:
            kw["n_meta_tokens"] = 8
        if self.window is not None:
            kw["window"] = min(self.window, 16)
        return self.replace(**kw)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytical parameter count, N (used for 6*N*D roofline terms)."""
        d, dh = self.d_model, self.dh
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "encdec":
            e = self.encdec
            # encoder self-attn + mlp, decoder self + cross + mlp
            attn = 2 * d * (self.n_heads + 2 * self.n_kv_heads) * dh + \
                self.n_heads * dh * d  # qkv (+bias ignored) + o ... approx
            enc_l = attn + 2 * d * self.d_ff
            dec_l = 2 * attn + 2 * d * self.d_ff
            return emb + e.n_enc_layers * enc_l + self.n_layers * dec_l
        if self.family == "ssm":
            di, ns = self.ssm_d_inner, self.ssm.d_state
            g = self.ssm.n_groups
            in_proj = d * (2 * di + 2 * g * ns + self.ssm_n_heads)
            out_proj = di * d
            per = in_proj + out_proj + di * self.ssm.d_conv
            return emb + self.n_layers * per
        # attention part
        if self.uses_mla:
            m = self.mla
            attn = (d * m.q_lora_rank
                    + m.q_lora_rank * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_dim)
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_dim)
                    + self.n_heads * m.v_dim * d)
        else:
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * dh \
                + self.n_heads * dh * d
        # mlp part per layer
        mult = 3 if self.gated_mlp else 2
        if self.family == "moe":
            mo = self.moe
            moe_mlp = mo.n_experts * mult * d * mo.expert_ff \
                + mo.n_shared * mult * d * (mo.shared_ff or mo.expert_ff) \
                + d * mo.n_experts  # router
            n_moe = self.n_layers - mo.first_k_dense
            dense_mlp = mult * d * (mo.dense_ff or self.d_ff)
            mlp_total = n_moe * moe_mlp + mo.first_k_dense * dense_mlp
        else:
            mlp_total = self.n_layers * mult * d * self.d_ff
        per_layer_extra = 0
        if self.family == "hybrid":
            di, ns = self.ssm_d_inner, self.ssm.d_state
            per_layer_extra = d * (2 * di + 2 * ns + self.ssm_n_heads) + di * d
        return emb + self.n_layers * (attn + per_layer_extra) + mlp_total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.family != "moe":
            return self.param_count()
        mo = self.moe
        full = self.param_count()
        mult = 3 if self.gated_mlp else 2
        n_moe = self.n_layers - mo.first_k_dense
        all_experts = n_moe * mo.n_experts * mult * self.d_model * mo.expert_ff
        active_experts = n_moe * mo.top_k * mult * self.d_model * mo.expert_ff
        return full - all_experts + active_experts


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode
    # decode shapes lower serve_step: 1 new token vs a seq_len KV cache.
    # long-context decode forces a sliding window on full-attention archs.
    force_window: Optional[int] = None


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode",
                             force_window=8192),
}


# ---------------------------------------------------------------------------
# Runtime / cache-system config (the paper's knobs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CacheConfig:
    """Distributed prompt cache configuration (paper §3-§4)."""
    bloom_capacity: int = 1_000_000   # paper: 1M entries
    bloom_fp_rate: float = 0.01       # paper: 1% target FP ratio
    compress: bool = True             # compressed state blobs (beyond-paper)
    compress_level: int = 1
    # blob codec: 'auto' picks zstd when the optional [edge] extra is
    # installed and falls back to stdlib zlib otherwise
    compress_codec: str = "auto"
    quantize: bool = False            # int8 KV blobs (beyond-paper)
    # v3 chunked blobs: layers per stream chunk (smaller = finer
    # download/compute pipelining, more per-chunk framing+codec
    # overhead). Uploads always write chunked containers; v2 blobs
    # remain readable.
    chunk_layers: int = 1
    max_ranges: int = 4               # prompt ranges registered per upload
    range_stride: int = 0             # >0: also register every k tokens
    min_match_tokens: int = 4         # minimum prefix worth fetching
    sync_interval_s: float = 1.0      # async catalog sync period
    # server-side LRU byte budget (0 = unbounded). Evicted keys linger in
    # the Bloom catalogs and surface as false positives — handled by the
    # paper's §3.3 fallback, so eviction needs no catalog invalidation.
    max_store_bytes: int = 0


@dataclass(frozen=True)
class NetConfig:
    """Simulated network (paper: 2.4GHz Wi-Fi 4).

    Calibrated so a 2.25MB blob takes ~0.86s (paper Table 3):
    2.25e6*8/0.86 ~= 21 Mb/s effective.
    """
    bandwidth_bps: float = 21e6
    rtt_s: float = 0.003              # observed small-op Redis latency


@dataclass(frozen=True)
class DeviceClass:
    """Device performance model for edge-latency emulation (paper Table 1)."""
    name: str
    flops: float                      # effective sustained FLOP/s
    # calibration: gemma3-270m prefill of 405 tok in 12.58s on Pi Zero 2W
    #   6*N*D flops = 6*268e6*405 = 6.5e11 -> ~5.2e10 eff FLOP/s... but the
    #   A53 does ~2-4 GFLOP/s/core*4; llama.cpp Q-quantized. We calibrate
    #   empirically per model in perfmodel.py; `flops` is the default.


PI_ZERO_2W = DeviceClass("pi-zero-2w", 2.1e9)
PI_5 = DeviceClass("pi-5", 38e9)
