"""Deterministic generators for the three replay mixes.

Every generator has the same shape::

    mix(n_requests, seed=0, rate_per_s=8.0, **mix_kw) -> List[WorkloadRequest]

Arrivals follow a seeded Poisson process (exponential gaps) so replay
drives the gateway the way production traffic would — bursty, not a
closed loop. Text is synthetic but word-stable: the same seed always
produces the same token ids through :class:`WordHashTokenizer`, which
is what makes cross-run token-identity checks possible.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, List, Tuple

Message = Tuple[str, str]                      # (role, content)


@dataclass
class WorkloadRequest:
    """One replayable chat request."""
    tenant: str
    messages: Tuple[Message, ...]
    max_new_tokens: int = 8
    arrival_s: float = 0.0                      # offset from replay start
    session: str = ""                           # agent-loop session id
    mix: str = ""

    def body(self, stream: bool = False) -> dict:
        """The OpenAI chat-completions request body for this entry."""
        return {
            "messages": [{"role": r, "content": c}
                         for r, c in self.messages],
            "max_tokens": self.max_new_tokens,
            "stream": stream,
            "user": self.tenant,
        }


def _words(rng: random.Random, n: int) -> str:
    return " ".join(f"w{rng.randrange(10_000)}" for _ in range(n))


def _arrivals(rng: random.Random, n: int, rate_per_s: float
              ) -> List[float]:
    t, out = 0.0, []
    for _ in range(n):
        t += rng.expovariate(rate_per_s) if rate_per_s > 0 else 0.0
        out.append(t)
    return out


# ---------------------------------------------------------------------------
# mix 1: customer support — one hot system prompt
# ---------------------------------------------------------------------------

def customer_support(n_requests: int, seed: int = 0,
                     rate_per_s: float = 8.0, n_tenants: int = 3,
                     system_words: int = 48, question_words: int = 6,
                     max_new_tokens: int = 8) -> List[WorkloadRequest]:
    """Every request shares one long system prompt; the user question
    is short and unique. Cache behaviour: one cold upload, then every
    request is a long-prefix partial hit."""
    rng = random.Random(seed)
    system = ("You are the support assistant for AcmeEdge devices. "
              + _words(rng, system_words))
    arrivals = _arrivals(rng, n_requests, rate_per_s)
    out = []
    for i in range(n_requests):
        q = f"ticket {i}: " + _words(rng, question_words)
        out.append(WorkloadRequest(
            tenant=f"support-{rng.randrange(n_tenants)}",
            messages=(("system", system), ("user", q)),
            max_new_tokens=max_new_tokens,
            arrival_s=arrivals[i], mix="support"))
    return out


# ---------------------------------------------------------------------------
# mix 2: RAG — Zipf-popular document pool
# ---------------------------------------------------------------------------

def _zipf_pick(rng: random.Random, n: int, a: float) -> int:
    """Index in [0, n) with P(i) ~ 1/(i+1)^a (finite Zipf, inverse CDF)."""
    weights = [1.0 / (i + 1) ** a for i in range(n)]
    total = sum(weights)
    x = rng.random() * total
    for i, w in enumerate(weights):
        x -= w
        if x <= 0:
            return i
    return n - 1


def rag(n_requests: int, seed: int = 0, rate_per_s: float = 8.0,
        n_tenants: int = 2, n_docs: int = 12, docs_per_request: int = 2,
        zipf_a: float = 1.2, doc_words: int = 24, question_words: int = 5,
        max_new_tokens: int = 8) -> List[WorkloadRequest]:
    """Requests stuff ``docs_per_request`` documents drawn from a
    Zipf-popular pool, *sorted most-popular-first*, so the hot head
    document(s) form a shared prefix across requests even when the
    tail documents differ."""
    rng = random.Random(seed)
    docs = [f"[doc {d}] " + _words(rng, doc_words) for d in range(n_docs)]
    arrivals = _arrivals(rng, n_requests, rate_per_s)
    out = []
    for i in range(n_requests):
        picked = set()
        while len(picked) < min(docs_per_request, n_docs):
            picked.add(_zipf_pick(rng, n_docs, zipf_a))
        context = [("system", docs[d]) for d in sorted(picked)]
        q = f"query {i}: " + _words(rng, question_words)
        out.append(WorkloadRequest(
            tenant=f"rag-{rng.randrange(n_tenants)}",
            messages=tuple(context) + (("user", q),),
            max_new_tokens=max_new_tokens,
            arrival_s=arrivals[i], mix="rag"))
    return out


# ---------------------------------------------------------------------------
# mix 3: agent loops — growing conversation prefixes
# ---------------------------------------------------------------------------

def agent_loops(n_requests: int, seed: int = 0, rate_per_s: float = 8.0,
                n_sessions: int = 3, step_words: int = 10,
                max_new_tokens: int = 8) -> List[WorkloadRequest]:
    """``n_sessions`` interleaved agent sessions; each turn appends a
    tool observation to the transcript, so turn *t*'s prompt extends
    turn *t-1*'s. The cache serves every turn after the first from the
    previous turn's uploaded ranges."""
    rng = random.Random(seed)
    arrivals = _arrivals(rng, n_requests, rate_per_s)
    transcripts: Dict[int, List[Message]] = {
        s: [("system", f"agent session {s}: plan and act. "
             + _words(rng, step_words))]
        for s in range(n_sessions)
    }
    out = []
    for i in range(n_requests):
        s = i % n_sessions                      # round-robin keeps every
        turn = len(transcripts[s])              # session growing evenly
        transcripts[s].append(
            ("tool", f"step {turn}: " + _words(rng, step_words)))
        out.append(WorkloadRequest(
            tenant=f"agent-{s}",
            messages=tuple(transcripts[s]),
            max_new_tokens=max_new_tokens,
            arrival_s=arrivals[i],
            session=f"s{s}", mix="agent"))
    return out


MIXES = {
    "support": customer_support,
    "rag": rag,
    "agent": agent_loops,
}
