"""Multi-tenant workload replay: three production prompt mixes.

JAX-free, fully deterministic request generators (seeded stdlib
``random``) used by ``benchmarks/gateway_load.py`` and the gateway
tests. Each mix models a different prefix-sharing structure — the
variable the paper's distributed prompt cache exploits:

* ``support`` — customer support: every request opens with one hot
  system prompt; only the short user question varies. The system
  prefix is cached once and served to everyone.
* ``rag`` — retrieval augmentation: requests stuff Zipf-popular
  documents before the question. Docs are ordered most-popular-first
  so the popular head forms a shared, cacheable prefix.
* ``agent`` — agent loops: each session's conversation grows turn by
  turn; request *i*'s full prompt is a strict prefix of request
  *i+1*'s, so every turn resumes from the previous turn's cache.
"""
from repro.workloads.mixes import (  # noqa: F401
    MIXES, WorkloadRequest, agent_loops, customer_support, rag,
)
