"""CLI: ``python -m repro.analysis src/ [more paths] [options]``.

Exit status 0 when clean, 1 on live violations OR stale baseline
entries. Stdlib-only — safe to run in the lint stage before any
project dependency is installed.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.checker import (ALL_RULES, check_paths,
                                    default_baseline_path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="project-specific static checks (R1-R6); see "
                    "docs/analysis.md for the rule catalog")
    ap.add_argument("paths", nargs="+",
                    help="files or directory roots to scan (a root is "
                         "treated as a sys.path entry for module-name "
                         "resolution, e.g. src/)")
    ap.add_argument("--rules", default=",".join(ALL_RULES),
                    help="comma-separated subset, e.g. R1,R4 "
                         "(default: all)")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON (default: "
                         "analysis_baseline.json next to the first "
                         "scan root)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline file (report raw)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable report on stdout")
    args = ap.parse_args(argv)

    rules = tuple(r.strip() for r in args.rules.split(",") if r.strip())
    bad = [r for r in rules if r not in ALL_RULES]
    if bad:
        ap.error(f"unknown rule(s) {bad}; known: {', '.join(ALL_RULES)}")

    baseline = None
    if not args.no_baseline:
        baseline = args.baseline or default_baseline_path(args.paths)

    rep = check_paths(args.paths, rules=rules, baseline_path=baseline)
    print(rep.to_json() if args.as_json else rep.render())
    return 1 if rep.failed else 0


if __name__ == "__main__":
    sys.exit(main())
