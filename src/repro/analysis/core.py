"""Shared plumbing for the project static checker.

One :class:`SourceFile` per ``.py`` file (parsed once, shared by every
rule), :class:`Finding` as the single violation currency, inline
suppressions, and the checked-in baseline.

Suppression syntax (one rule, one line)::

    t0 = time.monotonic()   # repro: allow[R3] clock-source definition

The comment silences exactly the named rule on exactly that physical
line. Anything broader — a whole-file or whole-class exception — goes
in the baseline file instead, where it carries a reason and is checked
for staleness: a baseline entry that no longer matches a live violation
FAILS the run, so the baseline can only shrink, never rot.
"""
from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set

_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\[(R\d+)\]\s*(.*)")


@dataclass(frozen=True)
class Finding:
    """One rule violation.

    ``key`` is the stable identity used for baseline matching: it never
    contains line numbers, so unrelated edits can't detach a baseline
    entry from the violation it documents.
    """

    rule: str                  # "R1".."R5"
    path: str                  # path as scanned (repo-relative in CI)
    line: int                  # 1-based; 0 = file/graph-level finding
    message: str
    key: str

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.rule} {self.message}"


@dataclass
class SourceFile:
    path: str                  # filesystem path
    relpath: str               # path relative to the scan root
    modname: str               # dotted module name ("" outside a package)
    source: str
    tree: ast.AST
    # line -> rules inline-allowed on that line
    allow: Dict[int, Set[str]] = field(default_factory=dict)

    @classmethod
    def load(cls, path: str, relpath: str, modname: str) -> "SourceFile":
        with open(path, "r", encoding="utf-8") as f:
            source = f.read()
        tree = ast.parse(source, filename=path)
        allow: Dict[int, Set[str]] = {}
        for i, text in enumerate(source.splitlines(), start=1):
            m = _ALLOW_RE.search(text)
            if m:
                allow.setdefault(i, set()).add(m.group(1))
        return cls(path, relpath, modname, source, tree, allow)

    def allowed(self, rule: str, line: int) -> bool:
        return rule in self.allow.get(line, ())


def iter_py_files(root: str):
    """Yield (path, relpath) for every ``.py`` under ``root`` (which may
    itself be a single file), skipping caches."""
    root = os.path.normpath(root)
    if os.path.isfile(root):
        yield root, os.path.basename(root)
        return
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__", ".git"))
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                path = os.path.join(dirpath, fn)
                yield path, os.path.relpath(path, root)


def modname_for(root: str, relpath: str) -> str:
    """Dotted module name of ``relpath`` when ``root`` is on sys.path
    (the ``src/`` layout); ``foo/__init__.py`` -> ``foo``."""
    parts = relpath.replace(os.sep, "/").split("/")
    if parts[-1] == "__init__.py":
        parts = parts[:-1]
    else:
        parts[-1] = parts[-1][:-3]
    return ".".join(p for p in parts if p)


def load_tree(root: str) -> List[SourceFile]:
    out = []
    for path, relpath in iter_py_files(root):
        out.append(SourceFile.load(path, relpath,
                                   modname_for(root, relpath)))
    return out


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

@dataclass
class Baseline:
    """Checked-in intentional exceptions: ``{"entries": [{"rule", "key",
    "reason"}, ...]}``. Matching is exact on (rule, key)."""

    entries: List[dict] = field(default_factory=list)
    path: Optional[str] = None

    @classmethod
    def load(cls, path: Optional[str]) -> "Baseline":
        if path is None or not os.path.exists(path):
            return cls([], path)
        with open(path, "r", encoding="utf-8") as f:
            data = json.load(f)
        entries = list(data.get("entries", []))
        for e in entries:
            if not (isinstance(e, dict) and e.get("rule")
                    and e.get("key") and e.get("reason")):
                raise ValueError(
                    f"baseline entry needs rule/key/reason: {e!r}")
        return cls(entries, path)

    def apply(self, findings: Sequence[Finding]):
        """Split findings into (live, suppressed) and return the stale
        baseline entries (matched nothing — they must be deleted)."""
        by_key = {(e["rule"], e["key"]): e for e in self.entries}
        live, suppressed, hit = [], [], set()
        for f in findings:
            e = by_key.get((f.rule, f.key))
            if e is None:
                live.append(f)
            else:
                suppressed.append(f)
                hit.add((f.rule, f.key))
        stale = [e for e in self.entries
                 if (e["rule"], e["key"]) not in hit]
        return live, suppressed, stale
