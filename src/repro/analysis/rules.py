"""R2/R3/R6 — per-file AST rules for the serving fabric.

R2: no blocking calls inside ``async def`` bodies. The gateway's HTTP
front door and the peer TCP server run their event loops on dedicated
threads; one ``time.sleep`` (or sync socket/file/subprocess call, or a
threading-lock ``acquire``) in a coroutine stalls every connection on
that loop. Blocking work belongs on the loop's executor
(``await loop.run_in_executor(...)``) — callables merely *passed* to
the executor are not flagged, and nested sync ``def``s are skipped
(they run wherever they are dispatched, not on the loop).

R3: no raw ``time.time()`` / ``time.perf_counter()`` /
``time.monotonic()`` on serving paths — every duration must come from
:mod:`repro.obs.clock` so all timings share one mockable monotonic
source. Offline tooling (launch/training/benchmarks) is out of scope;
``obs/clock.py`` is the single sanctioned call site.

R6: no silent swallows of the fabric's failure contract. Every
``except TransportError`` / ``except ChunkError`` handler on a serving
path must visibly *do something with the failure*: fall down the plan
(``raise`` / ``continue`` / ``break`` / ``return``), use the bound
exception (``except ... as e`` with ``e`` referenced), or record an
outcome (a ``FLIGHT.record/trigger``, metrics ``inc/observe``,
``mark_suspect``, ledger ``note_attempt/commit``, or a logger
``warning/error/exception`` call). A handler that only rebinds state
(``st = None``) or ``pass``es erases the failure from every artifact
the chaos drills assert on — the degradation happened but nothing can
ever show why.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.core import Finding, SourceFile

# R2 blocklist -------------------------------------------------------------
# module-attribute calls that block the calling thread
BLOCKING_MODULE_CALLS = {
    "time": {"sleep"},
    "subprocess": {"run", "call", "check_call", "check_output", "Popen"},
    "socket": {"create_connection", "getaddrinfo", "gethostbyname"},
    "os": {"system", "popen", "waitpid"},
}
# builtins that block (sync file I/O)
BLOCKING_BUILTINS = {"open"}
# method names that block regardless of receiver (sync lock protocol);
# ``await x.acquire()`` (asyncio primitives) is exempt
BLOCKING_METHODS = {"acquire"}

# R3 ----------------------------------------------------------------------
RAW_CLOCK_ATTRS = {"time", "perf_counter", "monotonic",
                   "time_ns", "perf_counter_ns", "monotonic_ns"}
# serving-path scope: everything scanned EXCEPT these relpath prefixes
R3_EXCLUDE_PREFIXES = (
    "repro/obs/clock.py",              # the sanctioned clock source
    "repro/launch/", "repro/training/", "repro/data/",
    "repro/models/", "repro/kernels/", "repro/configs/",
    "repro/roofline/", "repro/analysis/",
)

# R6 ----------------------------------------------------------------------
# exception names whose handlers must visibly handle (matched by the
# final name segment, so `state_io.ChunkError` counts)
R6_SWALLOWABLE = {"TransportError", "ChunkError"}
# call names (attr or bare) that count as recording an outcome
R6_HANDLED_CALLS = {"trigger", "record", "inc", "observe",
                    "mark_suspect", "note_attempt", "commit",
                    "warning", "error", "exception"}


def _time_bindings(tree: ast.AST) -> Set[str]:
    """Names in this file bound (at any scope) by ``from time import X``
    for a raw-clock ``X`` — plain ``import time`` is handled by matching
    attribute calls on the name ``time`` directly."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == "time" \
                and node.level == 0:
            for alias in node.names:
                if alias.name in RAW_CLOCK_ATTRS | {"sleep"}:
                    names.add(alias.asname or alias.name)
    return names


class _QualnameWalker(ast.NodeVisitor):
    """Base visitor tracking the enclosing def/class qualname."""

    def __init__(self) -> None:
        self.stack: List[str] = []

    @property
    def qualname(self) -> str:
        return ".".join(self.stack) or "<module>"

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    def _visit_def(self, node) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_FunctionDef = _visit_def
    visit_AsyncFunctionDef = _visit_def


def _call_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Attribute):
        base = f.value
        if isinstance(base, ast.Name):
            return f"{base.id}.{f.attr}"
        return f".{f.attr}"
    if isinstance(f, ast.Name):
        return f.id
    return "<dynamic>"


# ---------------------------------------------------------------------------
# R2
# ---------------------------------------------------------------------------

def _blocking_reason(call: ast.Call, awaited: bool,
                     from_time: Set[str]) -> str:
    f = call.func
    if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
        mod, attr = f.value.id, f.attr
        if attr in BLOCKING_MODULE_CALLS.get(mod, ()):
            return f"blocking call {mod}.{attr}()"
    if isinstance(f, ast.Attribute) and f.attr in BLOCKING_METHODS \
            and not awaited:
        return (f"sync lock protocol .{f.attr}() (await an asyncio "
                "primitive or run on the executor)")
    if isinstance(f, ast.Name):
        if f.id in BLOCKING_BUILTINS:
            return f"blocking builtin {f.id}()"
        if f.id in from_time and f.id.startswith("sleep"):
            return "blocking call sleep() (use asyncio.sleep)"
    return ""


def check_blocking_in_async(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    from_time = _time_bindings(sf.tree)

    class V(_QualnameWalker):
        def __init__(self) -> None:
            super().__init__()
            self.async_depth = 0

        def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
            # a nested sync def does not run on the event loop
            saved, self.async_depth = self.async_depth, 0
            self._visit_def(node)
            self.async_depth = saved

        def visit_AsyncFunctionDef(self, node) -> None:
            self.async_depth += 1
            self._visit_def(node)
            self.async_depth -= 1

        def visit_Lambda(self, node: ast.Lambda) -> None:
            pass                       # passed elsewhere, not run inline

        def visit_Await(self, node: ast.Await) -> None:
            if isinstance(node.value, ast.Call):
                self._check(node.value, awaited=True)
                for child in ast.iter_child_nodes(node.value):
                    self.visit(child)
            else:
                self.generic_visit(node)

        def visit_Call(self, node: ast.Call) -> None:
            self._check(node, awaited=False)
            self.generic_visit(node)

        def _check(self, node: ast.Call, awaited: bool) -> None:
            if not self.async_depth:
                return
            reason = _blocking_reason(node, awaited, from_time)
            if reason:
                findings.append(Finding(
                    "R2", sf.path, node.lineno,
                    f"{reason} inside `async def {self.stack[-1]}` — "
                    f"dispatch to an executor instead",
                    key=f"{sf.relpath}:{self.qualname}:"
                        f"{_call_name(node)}"))

    V().visit(sf.tree)
    return findings


# ---------------------------------------------------------------------------
# R3
# ---------------------------------------------------------------------------

def _r3_in_scope(relpath: str) -> bool:
    rel = relpath.replace("\\", "/")
    return not any(rel.startswith(p) for p in R3_EXCLUDE_PREFIXES)


def check_raw_clocks(sf: SourceFile) -> List[Finding]:
    if not _r3_in_scope(sf.relpath):
        return []
    findings: List[Finding] = []
    from_time = {n for n in _time_bindings(sf.tree) if n != "sleep"}

    class V(_QualnameWalker):
        def visit_Call(self, node: ast.Call) -> None:
            f = node.func
            bad = ""
            if isinstance(f, ast.Attribute) \
                    and isinstance(f.value, ast.Name) \
                    and f.value.id == "time" \
                    and f.attr in RAW_CLOCK_ATTRS:
                bad = f"time.{f.attr}()"
            elif isinstance(f, ast.Name) and f.id in from_time:
                bad = f"{f.id}()"
            if bad:
                findings.append(Finding(
                    "R3", sf.path, node.lineno,
                    f"raw clock {bad} on a serving path — use "
                    f"repro.obs.clock.monotonic()/wall()",
                    key=f"{sf.relpath}:{self.qualname}:{bad}"))
            self.generic_visit(node)

    V().visit(sf.tree)
    return findings


# ---------------------------------------------------------------------------
# R6
# ---------------------------------------------------------------------------

def _caught_names(handler: ast.ExceptHandler) -> Set[str]:
    """Final name segments of the exception types a handler catches."""
    t = handler.type
    elts = list(t.elts) if isinstance(t, ast.Tuple) else \
        ([t] if t is not None else [])
    names: Set[str] = set()
    for e in elts:
        if isinstance(e, ast.Name):
            names.add(e.id)
        elif isinstance(e, ast.Attribute):
            names.add(e.attr)
    return names


def _handler_handles(handler: ast.ExceptHandler) -> bool:
    """True when the handler body visibly handles the failure: control
    flow down the plan (raise/continue/break/return), any use of the
    bound exception name, or a call that records an outcome."""
    bound = handler.name
    for stmt in handler.body:
        for node in ast.walk(stmt):
            if isinstance(node, (ast.Raise, ast.Continue, ast.Break,
                                 ast.Return)):
                return True
            if bound and isinstance(node, ast.Name) \
                    and node.id == bound:
                return True
            if isinstance(node, ast.Call):
                f = node.func
                name = (f.attr if isinstance(f, ast.Attribute)
                        else f.id if isinstance(f, ast.Name) else "")
                if name in R6_HANDLED_CALLS:
                    return True
    return False


def check_silent_swallows(sf: SourceFile) -> List[Finding]:
    """R6: ``except TransportError/ChunkError`` on a serving path must
    fall down the plan or record a flight/metrics/ledger outcome —
    never swallow the fabric's failure contract silently."""
    if not _r3_in_scope(sf.relpath):
        return []
    findings: List[Finding] = []

    class V(_QualnameWalker):
        def visit_ExceptHandler(self, node: ast.ExceptHandler) -> None:
            caught = sorted(_caught_names(node) & R6_SWALLOWABLE)
            if caught and not _handler_handles(node):
                what = "/".join(caught)
                findings.append(Finding(
                    "R6", sf.path, node.lineno,
                    f"`except {what}` swallows the failure silently — "
                    f"fall down the plan (raise/continue/break/return) "
                    f"or record it (FLIGHT.record/trigger, metrics "
                    f"inc/observe, mark_suspect, ledger note_attempt/"
                    f"commit, logger warning/error)",
                    key=f"{sf.relpath}:{self.qualname}:{what}"))
            self.generic_visit(node)

    V().visit(sf.tree)
    return findings
