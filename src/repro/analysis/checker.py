"""Checker orchestration: load the tree, run R1–R6, apply inline
suppressions and the baseline, render a report."""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.analysis.core import Baseline, Finding, SourceFile, load_tree
from repro.analysis.imports import check_daemon_closure
from repro.analysis.locks import check_lock_order
from repro.analysis.rules import (check_blocking_in_async,
                                  check_raw_clocks,
                                  check_silent_swallows)
from repro.analysis.wire import check_wire_ops

ALL_RULES = ("R1", "R2", "R3", "R4", "R5", "R6")


@dataclass
class Report:
    live: List[Finding] = field(default_factory=list)
    suppressed_inline: List[Finding] = field(default_factory=list)
    suppressed_baseline: List[Finding] = field(default_factory=list)
    stale_baseline: List[dict] = field(default_factory=list)
    n_files: int = 0

    @property
    def failed(self) -> bool:
        return bool(self.live or self.stale_baseline)

    def render(self) -> str:
        lines: List[str] = []
        for f in sorted(self.live,
                        key=lambda f: (f.path, f.line, f.rule)):
            lines.append(f.render())
        for e in self.stale_baseline:
            lines.append(
                f"baseline: STALE entry {e['rule']}/{e['key']} "
                f"({e['reason']!r}) no longer matches any violation — "
                f"delete it so the baseline can only shrink")
        lines.append(
            f"repro.analysis: {self.n_files} files, "
            f"{len(self.live)} violation(s), "
            f"{len(self.suppressed_inline)} inline-allowed, "
            f"{len(self.suppressed_baseline)} baselined, "
            f"{len(self.stale_baseline)} stale baseline entr"
            f"{'y' if len(self.stale_baseline) == 1 else 'ies'}"
            f" -> {'FAIL' if self.failed else 'OK'}")
        return "\n".join(lines)

    def to_json(self) -> str:
        return json.dumps({
            "failed": self.failed,
            "n_files": self.n_files,
            "live": [f.__dict__ for f in self.live],
            "suppressed_inline": [f.__dict__
                                  for f in self.suppressed_inline],
            "suppressed_baseline": [f.__dict__
                                    for f in self.suppressed_baseline],
            "stale_baseline": self.stale_baseline,
        }, indent=2, sort_keys=True)


def run_rules(files: Sequence[SourceFile],
              rules: Sequence[str] = ALL_RULES) -> List[Finding]:
    files = list(files)
    findings: List[Finding] = []
    if "R1" in rules:
        findings.extend(check_daemon_closure(files))
    for sf in files:
        if "R2" in rules:
            findings.extend(check_blocking_in_async(sf))
        if "R3" in rules:
            findings.extend(check_raw_clocks(sf))
        if "R6" in rules:
            findings.extend(check_silent_swallows(sf))
    if "R4" in rules:
        findings.extend(check_wire_ops(files))
    if "R5" in rules:
        findings.extend(check_lock_order(files))
    return findings


def check_paths(paths: Sequence[str],
                rules: Sequence[str] = ALL_RULES,
                baseline_path: Optional[str] = None) -> Report:
    files: List[SourceFile] = []
    by_path: Dict[str, SourceFile] = {}
    for p in paths:
        for sf in load_tree(p):
            files.append(sf)
            by_path[sf.path] = sf

    findings = run_rules(files, rules)

    # inline suppressions first: an allowed line never reaches the
    # baseline, so `# repro: allow[...]` and baseline entries cannot
    # shadow each other
    kept: List[Finding] = []
    inline: List[Finding] = []
    for f in findings:
        sf = by_path.get(f.path)
        if sf is not None and sf.allowed(f.rule, f.line):
            inline.append(f)
        else:
            kept.append(f)

    baseline = Baseline.load(baseline_path)
    live, baselined, stale = baseline.apply(kept)
    return Report(live, inline, baselined, stale, len(files))


def default_baseline_path(paths: Sequence[str]) -> Optional[str]:
    """``analysis_baseline.json`` next to the first scan root (for
    ``python -m repro.analysis src/`` run from the repo root, that is
    the repo root)."""
    if not paths:
        return None
    root = os.path.normpath(paths[0])
    parent = os.path.dirname(root) or "."
    return os.path.join(parent, "analysis_baseline.json")
