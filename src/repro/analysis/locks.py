"""R5 — static lock-order graph over nested ``with <lock>`` scopes.

Locks are identified by *class*, lockdep-style: ``ClassName.attr`` for
``self.attr = threading.Lock()`` (every instance of the class shares
one node) or ``module.NAME`` for module-level locks. Edges come from:

* syntactic nesting — ``with self.a: ... with self.b: ...`` adds
  ``a -> b`` (and multi-item ``with a, b:`` acquires left-to-right);
* one interprocedural hop — a ``self.method()`` call made while a lock
  is held adds edges to every lock ``method`` itself acquires (same
  class only; deeper chains and cross-object calls are the runtime
  watchdog's job).

A cycle in the resulting digraph means two code paths can acquire the
same pair of lock classes in opposite orders — the classic ABBA
deadlock, reported with one witness edge per direction. Nesting the
*same* plain-Lock attribute is reported as a self-deadlock (an RLock
self-edge is legal reentrancy and ignored).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import Finding, SourceFile


@dataclass(frozen=True)
class LockDef:
    lock_id: str                   # "mod.Class.attr" or "mod.NAME"
    kind: str                      # "Lock" | "RLock"


def _lock_ctor_kind(v: ast.AST) -> Optional[str]:
    if (isinstance(v, ast.Call) and isinstance(v.func, ast.Attribute)
            and isinstance(v.func.value, ast.Name)
            and v.func.value.id == "threading"
            and v.func.attr in ("Lock", "RLock")):
        return v.func.attr
    return None


class _ClassScan:
    """Per-class view: lock attrs, and per-method (locks acquired,
    with-nesting edges)."""

    def __init__(self, sf: SourceFile, cls: ast.ClassDef):
        self.sf = sf
        self.cls = cls
        prefix = f"{sf.modname}.{cls.name}" if sf.modname else cls.name
        self.prefix = prefix
        self.locks: Dict[str, LockDef] = {}      # attr -> def
        for node in ast.walk(cls):
            if isinstance(node, ast.Assign):
                kind = _lock_ctor_kind(node.value)
                if kind:
                    for t in node.targets:
                        if isinstance(t, ast.Attribute) \
                                and isinstance(t.value, ast.Name) \
                                and t.value.id == "self":
                            self.locks[t.attr] = LockDef(
                                f"{prefix}.{t.attr}", kind)

    def lock_for(self, expr: ast.AST) -> Optional[LockDef]:
        if isinstance(expr, ast.Attribute) \
                and isinstance(expr.value, ast.Name) \
                and expr.value.id == "self":
            return self.locks.get(expr.attr)
        return None


Edge = Tuple[str, str]                 # (held lock_id, acquired lock_id)


def _scan_methods(scan: _ClassScan,
                  edges: Dict[Edge, Tuple[str, int]],
                  self_deadlocks: List[Finding]) -> Dict[str, Set[str]]:
    """Collect nesting edges per method; return {method name: set of
    lock_ids the method may acquire anywhere in its body}."""
    acquires: Dict[str, Set[str]] = {}
    calls_while_held: List[Tuple[str, str, int]] = []  # (held, meth, line)

    for item in scan.cls.body:
        if not isinstance(item, ast.FunctionDef):
            continue

        held: List[LockDef] = []
        meth_acquires: Set[str] = set()

        def visit(node: ast.AST) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)) and node is not item:
                return
            if isinstance(node, ast.With):
                pushed = 0
                for w in node.items:
                    ld = scan.lock_for(w.context_expr)
                    if ld is None:
                        continue
                    meth_acquires.add(ld.lock_id)
                    for h in held:
                        if h.lock_id == ld.lock_id:
                            if ld.kind == "Lock":
                                self_deadlocks.append(Finding(
                                    "R5", scan.sf.path, node.lineno,
                                    f"nested `with` on plain Lock "
                                    f"{ld.lock_id} — self-deadlock "
                                    f"(a Lock is not reentrant)",
                                    key=f"self:{ld.lock_id}"))
                        else:
                            edges.setdefault(
                                (h.lock_id, ld.lock_id),
                                (scan.sf.path, node.lineno))
                    held.append(ld)
                    pushed += 1
                for child in node.body:
                    visit(child)
                for _ in range(pushed):
                    held.pop()
                return
            if isinstance(node, ast.Call) and held \
                    and isinstance(node.func, ast.Attribute) \
                    and isinstance(node.func.value, ast.Name) \
                    and node.func.value.id == "self":
                calls_while_held.append(
                    (held[-1].lock_id, node.func.attr, node.lineno))
            for child in ast.iter_child_nodes(node):
                visit(child)

        for stmt in item.body:
            visit(stmt)
        acquires[item.name] = meth_acquires

    # one interprocedural hop: self.meth() under a held lock
    for held_id, meth, line in calls_while_held:
        for lock_id in acquires.get(meth, ()):
            if lock_id != held_id:
                edges.setdefault((held_id, lock_id),
                                 (scan.sf.path, line))
    return acquires


def _find_cycle(edges: Set[Edge]) -> Optional[List[str]]:
    graph: Dict[str, List[str]] = {}
    for a, b in sorted(edges):
        graph.setdefault(a, []).append(b)
    WHITE, GRAY, BLACK = 0, 1, 2
    color: Dict[str, int] = {}
    stack: List[str] = []

    def dfs(n: str) -> Optional[List[str]]:
        color[n] = GRAY
        stack.append(n)
        for m in graph.get(n, ()):
            c = color.get(m, WHITE)
            if c == GRAY:
                return stack[stack.index(m):] + [m]
            if c == WHITE:
                cyc = dfs(m)
                if cyc:
                    return cyc
        stack.pop()
        color[n] = BLACK
        return None

    for n in sorted(graph):
        if color.get(n, WHITE) == WHITE:
            cyc = dfs(n)
            if cyc:
                return cyc
    return None


def check_lock_order(files: List[SourceFile]) -> List[Finding]:
    edges: Dict[Edge, Tuple[str, int]] = {}
    findings: List[Finding] = []
    for sf in files:
        if sf.modname.startswith("repro.analysis"):
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef):
                scan = _ClassScan(sf, node)
                if scan.locks:
                    _scan_methods(scan, edges, findings)

    cycle = _find_cycle(set(edges))
    if cycle:
        hops = []
        for a, b in zip(cycle, cycle[1:]):
            path, line = edges[(a, b)]
            hops.append(f"{a} -> {b} (at {path}:{line})")
        first_path, first_line = edges[(cycle[0], cycle[1])]
        findings.append(Finding(
            "R5", first_path, first_line,
            "lock-order cycle: " + "; ".join(hops),
            key="cycle:" + "->".join(sorted(set(cycle)))))
    return findings
