"""R4 — wire-op consistency between senders and ``handle`` branches.

The cluster speaks a tiny string-op RPC: clients and peers send
``transport.request(op, payload)`` / ``directory.request(peer_id, op,
payload)`` / ``other.handle(op, payload)``; servers dispatch in
``handle(op, payload)`` methods (``CacheServer`` -> ``CachePeer`` ->
``DaemonHandler`` form a fall-through chain, so the handled set is the
union over every ``handle`` method in the tree).

Three drift modes are caught statically:

* an op *sent* with a string literal that no ``handle`` branch matches
  (a typo'd op returns ``{"ok": False, "error": "unknown op"}`` at
  runtime — silently, as a cache miss);
* an op *handled* but never sent from ``src/`` (dead wire surface —
  either delete the branch or baseline it with a reason, e.g. ops kept
  for operators/tests);
* payload-key drift: a send site with a **dict-literal** payload that
  omits a key the handler unconditionally subscripts
  (``payload["key"]`` raises ``KeyError`` server-side; ``.get`` calls
  are optional by construction and not required).

Send sites whose op or payload is a variable are skipped — dynamic
dispatch (e.g. the replication pump's ``kind`` variable) is invisible
to this rule and belongs in the baseline on the handler side.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import Finding, SourceFile

SEND_METHODS = ("request", "request_stream", "handle")
HANDLER_METHOD = "handle"


@dataclass
class SendSite:
    op: str
    path: str
    relpath: str
    line: int
    # None => payload not a plain dict literal (unknown keys, skip drift)
    payload_keys: Optional[Set[str]] = None


@dataclass
class HandlerBranch:
    op: str
    path: str
    relpath: str
    line: int
    owner: str                     # e.g. "CacheServer.handle"
    required_keys: Set[str] = field(default_factory=set)


def _literal_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _dict_literal_keys(node: ast.AST) -> Optional[Set[str]]:
    """Keys of a plain dict literal; None if not a literal or if it has
    computed keys / ``**`` spreads (full key set unknowable)."""
    if not isinstance(node, ast.Dict):
        return None
    keys: Set[str] = set()
    for k in node.keys:
        if k is None:                  # **spread
            return None
        s = _literal_str(k)
        if s is None:
            return None
        keys.add(s)
    return keys


def collect_send_sites(sf: SourceFile) -> List[SendSite]:
    out: List[SendSite] = []
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in SEND_METHODS):
            continue
        # a ``handle`` *definition* body never calls self-dotted sends;
        # op is the first string literal among the first two positional
        # args (covers both request(op, ...) and request(peer_id, op, ...))
        op_idx = None
        for i, arg in enumerate(node.args[:2]):
            if _literal_str(arg) is not None:
                op_idx = i
                break
        if op_idx is None:
            continue                   # dynamic op — out of scope
        op = _literal_str(node.args[op_idx])
        payload_keys = None
        if len(node.args) > op_idx + 1:
            payload_keys = _dict_literal_keys(node.args[op_idx + 1])
        out.append(SendSite(op, sf.path, sf.relpath, node.lineno,
                            payload_keys))
    return out


def _op_literals(test: ast.AST) -> List[str]:
    """Ops matched by an ``if`` test of the form ``op == "x"`` or
    ``op in ("x", "y")`` (possibly ``or``-joined)."""
    ops: List[str] = []
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.Or):
        for v in test.values:
            ops.extend(_op_literals(v))
        return ops
    if isinstance(test, ast.Compare) and len(test.ops) == 1 \
            and isinstance(test.left, ast.Name) \
            and test.left.id == "op":
        cmp, right = test.ops[0], test.comparators[0]
        if isinstance(cmp, ast.Eq):
            s = _literal_str(right)
            if s is not None:
                ops.append(s)
        elif isinstance(cmp, ast.In) \
                and isinstance(right, (ast.Tuple, ast.List, ast.Set)):
            for elt in right.elts:
                s = _literal_str(elt)
                if s is not None:
                    ops.append(s)
    return ops


def _mentions(node: ast.AST, name: str) -> bool:
    return any(isinstance(n, ast.Name) and n.id == name
               for n in ast.walk(node))


def _required_keys(body: List[ast.stmt], param: str) -> Set[str]:
    """Keys the branch subscripts unconditionally. An ``if`` whose test
    itself inspects the payload (``if payload.get("ring"):``) guards
    optional keys — its body is excluded."""
    keys: Set[str] = set()

    def visit(node: ast.AST) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return
        if isinstance(node, ast.If) and _mentions(node.test, param):
            return                     # payload-guarded => optional keys
        if isinstance(node, ast.Subscript) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == param:
            s = _literal_str(node.slice)
            if s is not None:
                keys.add(s)
        for child in ast.iter_child_nodes(node):
            visit(child)

    for stmt in body:
        visit(stmt)
    return keys


def collect_handler_branches(sf: SourceFile) -> List[HandlerBranch]:
    out: List[HandlerBranch] = []
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.FunctionDef)
                and node.name == HANDLER_METHOD):
            continue
        params = [a.arg for a in node.args.args]
        if len(params) < 3 or params[1] != "op":
            continue                   # not the wire dispatch signature
        payload_param = params[2]
        owner = node.name
        # find enclosing class for a readable owner label
        for parent in ast.walk(sf.tree):
            if isinstance(parent, ast.ClassDef) \
                    and node in ast.walk(parent):
                owner = f"{parent.name}.{node.name}"
                break
        for stmt in ast.walk(node):
            if not isinstance(stmt, ast.If):
                continue
            for op in _op_literals(stmt.test):
                out.append(HandlerBranch(
                    op, sf.path, sf.relpath, stmt.lineno, owner,
                    _required_keys(stmt.body, payload_param)))
    return out


def check_wire_ops(files: List[SourceFile]) -> List[Finding]:
    sends: List[SendSite] = []
    branches: List[HandlerBranch] = []
    for sf in files:
        # skip the analysis package itself (op literals in docstrings
        # of helper code would self-trigger) and fixtures
        if sf.modname.startswith("repro.analysis"):
            continue
        sends.extend(collect_send_sites(sf))
        branches.extend(collect_handler_branches(sf))
    if not branches:
        return []                      # no wire surface in this tree

    handled: Dict[str, List[HandlerBranch]] = {}
    for b in branches:
        handled.setdefault(b.op, []).append(b)
    sent_ops = {s.op for s in sends}

    findings: List[Finding] = []
    seen_unknown: Set[Tuple[str, str]] = set()
    for s in sends:
        if s.op not in handled:
            k = (s.op, s.relpath)
            if k in seen_unknown:
                continue
            seen_unknown.add(k)
            findings.append(Finding(
                "R4", s.path, s.line,
                f"wire op {s.op!r} is sent here but no handle() branch "
                f"matches it — at runtime this is a silent "
                f"'unknown op' error",
                key=f"sent:{s.op}"))
            continue
        if s.payload_keys is None:
            continue
        required = set()
        for b in handled[s.op]:
            required |= b.required_keys
        missing = sorted(required - s.payload_keys)
        if missing:
            owners = ", ".join(sorted({b.owner for b in handled[s.op]}))
            findings.append(Finding(
                "R4", s.path, s.line,
                f"payload for wire op {s.op!r} omits key(s) "
                f"{missing} required by {owners}",
                key=f"drift:{s.op}:{','.join(missing)}:{s.relpath}"))

    for op in sorted(handled):
        if op in sent_ops:
            continue
        b = min(handled[op], key=lambda b: (b.relpath, b.line))
        findings.append(Finding(
            "R4", b.path, b.line,
            f"wire op {op!r} is handled by {b.owner} but never sent "
            f"from the scanned tree — dead wire surface (delete the "
            f"branch or baseline it with a reason)",
            key=f"handled:{op}"))
    return findings
