"""Project-specific static analysis + runtime concurrency watchdog.

Stdlib-only (importable from the lint stage and from the JAX-free
daemon fleet). Two halves:

* ``python -m repro.analysis src/`` — AST/import-graph checks R1–R6
  (daemon import hygiene, blocking-in-coroutine, raw clocks, wire-op
  consistency, static lock-order cycles). See ``docs/analysis.md``.
* :mod:`repro.analysis.watchdog` — opt-in runtime lock-order watchdog
  (``REPRO_LOCK_WATCHDOG=1``) that instruments ``threading.Lock`` /
  ``RLock`` and fails on acquisition-order cycles or blocking calls
  made while holding a lock.
"""
from repro.analysis.checker import (ALL_RULES, Report, check_paths,
                                    default_baseline_path, run_rules)
from repro.analysis.core import Baseline, Finding, SourceFile, load_tree
from repro.analysis.watchdog import (LockOrderViolation,
                                     LockOrderWatchdog, install,
                                     install_from_env, uninstall)

__all__ = [
    "ALL_RULES", "Baseline", "Finding", "LockOrderViolation",
    "LockOrderWatchdog", "Report", "SourceFile", "check_paths",
    "default_baseline_path", "install", "install_from_env",
    "load_tree", "run_rules", "uninstall",
]
