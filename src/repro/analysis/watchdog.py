"""Runtime lock-order watchdog (opt-in: ``REPRO_LOCK_WATCHDOG=1``).

The static R5 pass sees syntactic nesting inside one class; this
watchdog sees what actually happens: it wraps ``threading.Lock`` /
``threading.RLock`` so every acquisition records, per thread, which
lock *classes* were already held. Lock classes are lockdep-style —
identified by their creation site (``file:line`` of the ``Lock()``
call), so all instances born at one line share a node and an order
proven on any instance pair constrains all of them.

Two violation kinds are recorded (never raised in-line — a detector
that crashes the serving path it watches would mask the bug):

* **cycle** — a new held->acquired edge closes a cycle in the global
  lock-order graph: two threads can acquire the same lock classes in
  opposite orders (ABBA deadlock), reported with one witness per edge;
* **blocking-while-held** — ``time.sleep`` called while holding a
  watched lock (stalls every thread contending for it).

Same-class edges (two *instances* of one creation site nested, e.g.
in-proc peer A delegating to peer B) are not recorded: without lockdep
nesting annotations they cannot be told apart from reentrancy-safe
patterns, and the false-positive cost outweighs it.

Use :func:`install` / :func:`uninstall` (or the conftest hook), then
:func:`report` / :func:`check` at teardown. Locks created while the
watchdog is installed stay functional after ``uninstall`` — the
wrapper delegates to a real ``_thread`` lock underneath, and
``__getattr__`` forwarding keeps ``threading.Condition`` internals
(``_release_save`` / ``_acquire_restore`` / ``_is_owned``) working.
"""
from __future__ import annotations

import os
import sys
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

import _thread

ENV_VAR = "REPRO_LOCK_WATCHDOG"

_WATCHDOG_FILES = (os.sep + "analysis" + os.sep + "watchdog.py",)


def _creation_site() -> str:
    """file:line of the frame that called ``threading.Lock()`` —
    the lock's lockdep class."""
    f = sys._getframe(2)
    while f is not None and f.f_code.co_filename.endswith(
            _WATCHDOG_FILES):
        f = f.f_back
    if f is None:
        return "<unknown>"
    return f"{os.path.basename(f.f_code.co_filename)}:{f.f_lineno}"


def _acquire_site() -> str:
    f = sys._getframe(2)
    while f is not None and f.f_code.co_filename.endswith(
            _WATCHDOG_FILES):
        f = f.f_back
    if f is None:
        return "<unknown>"
    return f"{f.f_code.co_filename}:{f.f_lineno}"


@dataclass
class Violation:
    kind: str                      # "cycle" | "blocking-while-held"
    detail: str
    thread: str
    site: str

    def render(self) -> str:
        return (f"[{self.kind}] {self.detail} "
                f"(thread {self.thread}, at {self.site})")


class LockOrderWatchdog:
    def __init__(self) -> None:
        # leaf-only internal lock: a raw _thread lock so the watchdog
        # can never participate in the graphs it builds
        self._mu = _thread.allocate_lock()
        # (held class, acquired class) -> (thread, site) first witness
        self.edges: Dict[Tuple[str, str], Tuple[str, str]] = {}
        self.violations: List[Violation] = []
        self._cycles_seen: Set[frozenset] = set()
        self._tls = threading.local()
        self.n_acquires = 0

    # -- per-thread held stack -----------------------------------------
    def _held(self) -> List[Tuple[str, int]]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def on_acquired(self, class_id: str, inst_id: int) -> None:
        held = self._held()
        reentrant = any(i == inst_id for _, i in held)
        if not reentrant and held:
            site = _acquire_site()
            tname = threading.current_thread().name
            new_edges = []
            with self._mu:
                self.n_acquires += 1
                for hcls, hinst in held:
                    if hcls == class_id:
                        continue       # same-class: see module docstring
                    e = (hcls, class_id)
                    if e not in self.edges:
                        self.edges[e] = (tname, site)
                        new_edges.append(e)
                if new_edges:
                    self._check_cycles_locked()
        else:
            with self._mu:
                self.n_acquires += 1
        held.append((class_id, inst_id))

    def on_released(self, inst_id: int) -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i][1] == inst_id:
                del held[i]
                return

    def on_blocking_call(self, what: str) -> None:
        held = self._held()
        if not held:
            return
        classes = ", ".join(sorted({c for c, _ in held}))
        with self._mu:
            self.violations.append(Violation(
                "blocking-while-held",
                f"{what} while holding lock(s) {classes}",
                threading.current_thread().name, _acquire_site()))

    # -- cycle detection (called with self._mu held) --------------------
    def _check_cycles_locked(self) -> None:
        graph: Dict[str, List[str]] = {}
        for a, b in self.edges:
            graph.setdefault(a, []).append(b)
        color: Dict[str, int] = {}
        stack: List[str] = []

        def dfs(n: str) -> Optional[List[str]]:
            color[n] = 1
            stack.append(n)
            for m in graph.get(n, ()):
                c = color.get(m, 0)
                if c == 1:
                    return stack[stack.index(m):] + [m]
                if c == 0:
                    cyc = dfs(m)
                    if cyc:
                        return cyc
            stack.pop()
            color[n] = 2
            return None

        for n in sorted(graph):
            if color.get(n, 0) == 0:
                cyc = dfs(n)
                if cyc:
                    ident = frozenset(cyc)
                    if ident in self._cycles_seen:
                        return
                    self._cycles_seen.add(ident)
                    hops = []
                    for a, b in zip(cyc, cyc[1:]):
                        t, s = self.edges[(a, b)]
                        hops.append(f"{a} -> {b} [{t} at {s}]")
                    self.violations.append(Violation(
                        "cycle", "lock-order cycle: " + "; ".join(hops),
                        threading.current_thread().name,
                        _acquire_site()))
                    return

    # -- reporting ------------------------------------------------------
    def snapshot(self) -> dict:
        with self._mu:
            return {"n_acquires": self.n_acquires,
                    "n_edges": len(self.edges),
                    "violations": [v.render() for v in self.violations]}

    def report(self) -> str:
        snap = self.snapshot()
        lines = [f"lock watchdog: {snap['n_acquires']} acquisitions, "
                 f"{snap['n_edges']} order edges, "
                 f"{len(snap['violations'])} violation(s)"]
        lines.extend("  " + v for v in snap["violations"])
        return "\n".join(lines)

    def check(self) -> None:
        """Raise if any violation was recorded."""
        if self.violations:
            raise LockOrderViolation(self.report())


class LockOrderViolation(AssertionError):
    pass


# ---------------------------------------------------------------------------
# instrumented lock wrappers
# ---------------------------------------------------------------------------

class _WatchedLockBase:
    _factory = staticmethod(_thread.allocate_lock)

    def __init__(self, wd: LockOrderWatchdog):
        self._wd = wd
        self._inner = self._factory()
        self._class_id = _creation_site()

    def acquire(self, blocking: bool = True, timeout: float = -1):
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._wd.on_acquired(self._class_id, id(self))
        return got

    def release(self) -> None:
        self._inner.release()
        self._wd.on_released(id(self))

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __getattr__(self, name: str):
        # Condition internals (_release_save/_acquire_restore/_is_owned
        # on RLock) and anything else exotic go straight to the real
        # lock; a waiting thread runs no code while our bookkeeping is
        # briefly stale, so order recording stays sound.
        if name in ("_inner", "_wd", "_class_id"):
            raise AttributeError(name)
        return getattr(self._inner, name)

    def __repr__(self) -> str:
        return (f"<watched {type(self).__name__} "
                f"class={self._class_id} inner={self._inner!r}>")


class _WatchedLock(_WatchedLockBase):
    pass


class _WatchedRLock(_WatchedLockBase):
    _factory = staticmethod(_thread.RLock)


# ---------------------------------------------------------------------------
# install / uninstall
# ---------------------------------------------------------------------------

_active: Optional[LockOrderWatchdog] = None
_saved: dict = {}


def active() -> Optional[LockOrderWatchdog]:
    return _active


def install() -> LockOrderWatchdog:
    """Patch ``threading.Lock``/``RLock`` and ``time.sleep``. Returns
    the watchdog; idempotent while installed."""
    global _active
    if _active is not None:
        return _active
    wd = LockOrderWatchdog()
    _saved["Lock"] = threading.Lock
    _saved["RLock"] = threading.RLock
    real_sleep = _saved["sleep"] = time.sleep

    def make_lock():
        return _WatchedLock(wd)

    def make_rlock():
        return _WatchedRLock(wd)

    def watched_sleep(seconds):
        wd.on_blocking_call(f"time.sleep({seconds!r})")
        return real_sleep(seconds)

    threading.Lock = make_lock
    threading.RLock = make_rlock
    time.sleep = watched_sleep
    _active = wd
    return wd


def uninstall() -> Optional[LockOrderWatchdog]:
    """Restore the real primitives; returns the (now inert) watchdog.
    Already-created watched locks keep working — they own their inner
    lock and only append to the watchdog's records."""
    global _active
    if _active is None:
        return None
    threading.Lock = _saved.pop("Lock")
    threading.RLock = _saved.pop("RLock")
    time.sleep = _saved.pop("sleep")
    wd, _active = _active, None
    return wd


def install_from_env() -> Optional[LockOrderWatchdog]:
    if os.environ.get(ENV_VAR, "") not in ("", "0"):
        return install()
    return None
