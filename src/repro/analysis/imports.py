"""R1 — the daemon import closure stays JAX/numpy-free.

A cache-peer daemon (``python -m repro.core.net.daemon``) must start in
milliseconds and never drag an ML runtime into the fleet: one stray
module-level ``import jax`` anywhere in its transitive import closure
would cost every peer process hundreds of MB and seconds of startup.

This is a *static* walk of module-level imports (function-level lazy
imports are deliberately excluded — they are the sanctioned escape
hatch, paid only when the symbol is actually used), so it covers every
module the interpreter would execute at daemon import time, not just
the ones a smoke test happened to touch.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import Finding, SourceFile

BANNED_ROOTS = ("jax", "jaxlib", "numpy")
DAEMON_MODULE = "repro.core.net.daemon"


def module_level_imports(sf: SourceFile) -> List[Tuple[str, int]]:
    """(imported module name, line) for every import executed at module
    import time — anywhere outside a function body, including inside
    module-level ``if``/``try`` blocks and class bodies."""
    out: List[Tuple[str, int]] = []

    def walk(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            if isinstance(child, ast.Import):
                for alias in child.names:
                    out.append((alias.name, child.lineno))
            elif isinstance(child, ast.ImportFrom):
                base = _resolve_from(sf.modname, child)
                if base is None:
                    continue
                out.append((base, child.lineno))
                for alias in child.names:
                    if alias.name != "*":
                        # ``from pkg import sub`` may bind a submodule
                        out.append((f"{base}.{alias.name}",
                                    child.lineno))
            else:
                walk(child)

    walk(sf.tree)
    return out


def _resolve_from(modname: str, node: ast.ImportFrom) -> Optional[str]:
    if node.level == 0:
        return node.module
    # relative import: resolve against this module's package
    parts = modname.split(".")
    # a package's __init__ has modname == package name; the mapping from
    # SourceFile always names modules, so drop `level` trailing parts
    # (for modules, level=1 means "my package")
    if len(parts) < node.level:
        return None
    base = parts[:len(parts) - node.level]
    if node.module:
        base = base + node.module.split(".")
    return ".".join(base) if base else None


class ImportGraph:
    """Static module-level import graph over one scanned tree."""

    def __init__(self, files: Iterable[SourceFile]):
        self.by_mod: Dict[str, SourceFile] = {
            sf.modname: sf for sf in files if sf.modname}

    def _expand(self, name: str) -> List[str]:
        """A dotted import touches the module AND every ancestor
        package (``import a.b.c`` executes a, a.b, a.b.c)."""
        parts = name.split(".")
        return [".".join(parts[:i + 1]) for i in range(len(parts))]

    def closure(self, start: str) -> Dict[str, Tuple[str, int]]:
        """Modules reachable from ``start`` via module-level imports,
        mapped to (importer module, import line) — the edge that first
        reached them (for "how did this get here" reporting)."""
        seen: Dict[str, Tuple[str, int]] = {start: ("", 0)}
        stack = [start]
        while stack:
            mod = stack.pop()
            sf = self.by_mod.get(mod)
            if sf is None:
                continue
            for name, line in module_level_imports(sf):
                for cand in self._expand(name):
                    if cand in self.by_mod and cand not in seen:
                        seen[cand] = (mod, line)
                        stack.append(cand)
        return seen

    def chain(self, closure: Dict[str, Tuple[str, int]],
              mod: str) -> List[str]:
        out = [mod]
        while True:
            parent, _ = closure.get(out[-1], ("", 0))
            if not parent:
                break
            out.append(parent)
        return list(reversed(out))


def check_daemon_closure(files: List[SourceFile],
                         start: str = DAEMON_MODULE,
                         banned: Tuple[str, ...] = BANNED_ROOTS,
                         ) -> List[Finding]:
    graph = ImportGraph(files)
    if start not in graph.by_mod:
        return []                      # tree does not contain the daemon
    closure = graph.closure(start)
    findings: List[Finding] = []
    for mod in sorted(closure):
        sf = graph.by_mod[mod]
        flagged: Set[str] = set()
        for name, line in module_level_imports(sf):
            root = name.split(".")[0]
            if root in banned and root not in flagged:
                flagged.add(root)
                via = " -> ".join(graph.chain(closure, mod))
                findings.append(Finding(
                    "R1", sf.path, line,
                    f"daemon-reachable module {mod!r} imports {root!r} "
                    f"at module level (reached via {via})",
                    key=f"{mod}:{root}"))
    return findings
