"""Replay a :class:`FaultSchedule` against a live TCP fleet.

The driver is a pure translator: each :class:`FaultEvent` kind maps
onto exactly one supervisor control surface —

========== =====================================================
kind       applied as
========== =====================================================
kill       ``sup.kill(peer, hard=True)`` (SIGKILL, no drain)
revive     ``sup.restart(peer)`` (same id, same port, cold store)
bandwidth  ``sup.set_throttle(peer, bps)`` (silent collapse /
           ``bps=None`` restores)
corrupt    ``inject {corrupt_chunks: n}`` (flip a byte in the
           next n stream chunks — caught by per-chunk digests)
stall      ``inject {stall_chunk_s: s}`` (sleep before every
           chunk: a wedged ``get_chunks`` stream)
delay_ack  ``inject {delay_ack_s: s}`` (slow single-frame acks)
partition  ``inject {partition_inbound: true}`` (asymmetric: the
           peer receives but never answers — its own outbound
           gossip/replication still flows)
heal       ``inject {reset: true}`` (clears every injected flag)
========== =====================================================

``advance(step)`` fires everything scheduled in ``(last, step]`` in
canonical order and returns the fired events; applying to a peer
that is currently dead is recorded-and-skipped, not an error (a
schedule may well corrupt a peer another event already killed —
that interleaving is the point of the drill).
"""
from __future__ import annotations

from typing import List, Optional

from repro.chaos.schedule import FaultEvent, FaultSchedule
from repro.core.transport import TransportError
from repro.obs.flight import FLIGHT

# FaultEvent kind -> PeerServer.chaos flag for the inject-op kinds
_INJECT_FLAGS = {"corrupt": "corrupt_chunks",
                 "stall": "stall_chunk_s",
                 "delay_ack": "delay_ack_s",
                 "partition": "partition_inbound"}


class FaultDriver:
    def __init__(self, sup, schedule: FaultSchedule):
        self.sup = sup
        self.schedule = schedule
        self.cursor = 0            # first step not yet fired
        self.applied: List[FaultEvent] = []
        self.skipped: List[FaultEvent] = []

    def advance(self, step: int) -> List[FaultEvent]:
        """Fire every event scheduled in ``(cursor-1, step]``."""
        fired: List[FaultEvent] = []
        for s in range(self.cursor, step + 1):
            for ev in self.schedule.at(s):
                self._apply(ev)
                fired.append(ev)
        self.cursor = step + 1
        return fired

    def finish(self) -> List[FaultEvent]:
        """Fire everything left on the schedule (the trailing heals)."""
        return self.advance(max((e.step for e in self.schedule.events),
                                default=self.cursor))

    # ------------------------------------------------------------------
    def _apply(self, ev: FaultEvent) -> None:
        FLIGHT.record("chaos.apply", step=ev.step, kind=ev.kind,
                      peer=ev.peer, **{str(k): v
                                       for k, v in ev.args.items()})
        try:
            if ev.kind == "kill":
                self.sup.kill(ev.peer, hard=True)
            elif ev.kind == "revive":
                self.sup.restart(ev.peer)
            elif ev.kind == "bandwidth":
                self.sup.set_throttle(ev.peer, ev.args.get("bps"))
            elif ev.kind == "heal":
                self.sup.inject_faults(ev.peer, reset=True)
            elif ev.kind in _INJECT_FLAGS:
                flag = _INJECT_FLAGS[ev.kind]
                val: object = True if ev.kind == "partition" else \
                    (ev.args.get("chunks") if ev.kind == "corrupt"
                     else ev.args.get("seconds"))
                self.sup.inject_faults(ev.peer, chaos={flag: val})
            else:
                raise ValueError(f"unknown fault kind {ev.kind!r}")
        except TransportError as e:
            # target currently dead (killed earlier in the schedule):
            # record the interleaving and move on — the drill asserts
            # on what was APPLIED, not what was scheduled
            self.skipped.append(ev)
            FLIGHT.record("chaos.skip", step=ev.step, kind=ev.kind,
                          peer=ev.peer, error=repr(e))
            return
        self.applied.append(ev)

    # ------------------------------------------------------------------
    def applied_order(self) -> List[str]:
        """Fingerprints of the events actually applied, in fire
        order — the replay-determinism probe for live runs."""
        return [e.fingerprint() for e in self.applied]

    def heal_all(self, peers: Optional[List[str]] = None) -> None:
        """Best-effort terminal heal: clear chaos flags and throttles
        on every (live) peer so teardown never races leftover faults."""
        for pid in (peers if peers is not None else
                    list(self.sup.procs)):
            try:
                self.sup.inject_faults(pid, reset=True)
                self.sup.set_throttle(pid, None)
            except TransportError as e:
                FLIGHT.record("chaos.heal_failed", peer=pid,
                              error=repr(e))
