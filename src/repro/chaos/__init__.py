"""Deterministic fault-injection fabric (the chaos layer).

Faults are *data*, not code paths: a :class:`FaultSchedule` is a
seed-generated, JSON-serializable list of :class:`FaultEvent` entries
(peer kills/revives, asymmetric partitions, silent bandwidth
collapse, chunk corruption, stalled streams, delayed acks) that a
:class:`FaultDriver` replays against a live
:class:`~repro.core.net.supervisor.PeerSupervisor` fleet — the same
schedule (same seed) always produces the same events in the same
order, so every chaos failure is replayable from one integer.

For in-process fabrics there are wrapper injectors
(:class:`ChaosLink`, :class:`ChaosSimNetwork`) that corrupt or drop
at the transport boundary without any real sockets.

The drill that exercises all of it end to end lives in
``benchmarks/chaos_drill.py``; the graceful-degradation machinery it
validates (circuit breakers, deadline propagation, hedged fetches,
mid-stream cancel) lives in the core — see ``docs/robustness.md``.
"""
from repro.chaos.driver import FaultDriver
from repro.chaos.injectors import ChaosLink, ChaosSimNetwork
from repro.chaos.schedule import FaultEvent, FaultSchedule

__all__ = ["FaultDriver", "FaultEvent", "FaultSchedule",
           "ChaosLink", "ChaosSimNetwork"]
