"""In-process chaos injectors: fault wrappers for fabrics without
real sockets.

On the TCP fabric, faults are injected server-side (the daemon's
``inject`` op mutates ``PeerServer.chaos``) because that is where
real failures live. In-process fabrics (``Fabric.sim``, unit tests)
have no daemon to inject into, so these wrappers apply the same fault
vocabulary at the transport boundary instead:

* :class:`ChaosLink` wraps any peer link / transport (``TCPPeerLink``,
  ``PeerTransport``, ``InProcTransport``) and can drop requests
  (``TransportError``), delay them, or corrupt streamed chunks before
  the client's integrity checks see them.
* :class:`ChaosSimNetwork` wraps a
  :class:`~repro.core.netsim.SimNetwork` and degrades its modeled
  bandwidth/RTT by a factor — silent congestion for the simulated
  fabric, visible only through the estimator's calibration drift.

Both mutate live (set attributes mid-test) and default to
transparent passthrough, so wrapping is free until a fault is armed.
"""
from __future__ import annotations

from typing import Optional

from repro.core.transport import TransportError
from repro.obs.flight import FLIGHT


class ChaosLink:
    """Transparent proxy over a peer link with armable faults.

    ``drop_requests`` — raise :class:`TransportError` on every request
    (an unreachable peer); ``fail_next`` — raise on the next N
    requests then auto-disarm (a flapping peer); ``corrupt_chunks`` —
    flip the first byte of the next N streamed chunks;
    ``delay_s`` — advance the wrapped clock / sleep before each
    request (only meaningful on wall links).
    """

    def __init__(self, link):
        self._link = link
        self.drop_requests = False
        self.fail_next = 0
        self.corrupt_chunks = 0
        self.delay_s = 0.0

    # attribute passthrough keeps the wrapper drop-in for the
    # directory (peer_id, net, catalog wiring, close, ...)
    def __getattr__(self, name):
        return getattr(self._link, name)

    def _gate(self, op: str) -> None:
        if self.delay_s:
            import time
            time.sleep(self.delay_s)
        if self.drop_requests or self.fail_next > 0:
            if self.fail_next > 0:
                self.fail_next -= 1
            FLIGHT.record("chaos.fault", kind="drop_request", op=op,
                          peer=getattr(self._link, "peer_id", "?"))
            raise TransportError(
                f"chaos: injected drop for op {op!r}")

    def request(self, op, payload, **kw):
        self._gate(op)
        return self._link.request(op, payload, **kw)

    def request_stream(self, op, payload, on_chunk, **kw):
        self._gate(op)

        def tap(chunk, dt, nb):
            if self.corrupt_chunks > 0 and chunk.get("chunk"):
                self.corrupt_chunks -= 1
                b = bytes(chunk["chunk"])
                chunk = dict(chunk,
                             chunk=bytes([b[0] ^ 0xFF]) + b[1:])
                FLIGHT.record("chaos.fault", kind="corrupt_chunk",
                              op=op,
                              peer=getattr(self._link, "peer_id", "?"))
            on_chunk(chunk, dt, nb)

        return self._link.request_stream(op, payload, tap, **kw)


class ChaosSimNetwork:
    """A :class:`SimNetwork` view with degradable bandwidth/RTT.

    ``degrade(bw_factor, rtt_factor)`` scales the modeled link;
    ``heal()`` restores nominal. The planner keeps pricing from the
    estimator's (stale) beliefs while modeled transfers slow down —
    exactly the silent-bandwidth-collapse miscalibration the drift
    alarm exists to catch."""

    def __init__(self, net):
        self._net = net
        self.bw_factor = 1.0
        self.rtt_factor = 1.0

    @property
    def bandwidth_bps(self) -> float:
        return self._net.bandwidth_bps * self.bw_factor

    @property
    def rtt_s(self) -> float:
        return self._net.rtt_s * self.rtt_factor

    def transfer_time(self, nbytes: int) -> float:
        return self.rtt_s + nbytes * 8.0 / max(self.bandwidth_bps, 1.0)

    def degrade(self, bw_factor: float = 0.1,
                rtt_factor: Optional[float] = None) -> None:
        self.bw_factor = bw_factor
        if rtt_factor is not None:
            self.rtt_factor = rtt_factor
        FLIGHT.record("chaos.fault", kind="sim_degrade",
                      bw_factor=self.bw_factor,
                      rtt_factor=self.rtt_factor)

    def heal(self) -> None:
        self.bw_factor = 1.0
        self.rtt_factor = 1.0
