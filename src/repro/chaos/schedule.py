"""Seeded, replayable fault schedules.

A schedule is generated once from ``(seed, peers, n_steps)`` by a
private ``random.Random(seed)`` — never from wall time, never from
``hash()`` — so the SAME seed always yields the SAME events at the
SAME steps targeting the SAME peers, across processes and
PYTHONHASHSEED values. ``event_order()`` is the canonical replay
fingerprint the chaos drill asserts equality on.

Events are step-indexed (the drill advances one request = one step)
rather than wall-clock-stamped: wall time is exactly the
nondeterminism a replayable schedule must not depend on.
"""
from __future__ import annotations

import json
import random
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Sequence

# every kind the driver knows how to apply; generate() draws from the
# injectable subset and pairs each fault with its heal
KINDS = ("kill", "revive", "bandwidth", "corrupt", "stall",
         "delay_ack", "partition", "heal")


@dataclass
class FaultEvent:
    step: int                  # schedule step the driver fires this at
    kind: str                  # one of KINDS
    peer: str                  # target peer id
    # kind-specific knobs (bps for bandwidth, chunks for corrupt /
    # close, seconds for stall/delay) — JSON-safe scalars only
    args: Dict[str, object] = field(default_factory=dict)

    def fingerprint(self) -> str:
        args = ",".join(f"{k}={self.args[k]}"
                        for k in sorted(self.args))
        return f"{self.step}:{self.kind}:{self.peer}:{args}"


class FaultSchedule:
    """An ordered list of :class:`FaultEvent` plus its provenance."""

    def __init__(self, events: Sequence[FaultEvent], seed: int = 0,
                 n_steps: int = 0):
        self.events: List[FaultEvent] = sorted(
            events, key=lambda e: (e.step, e.kind, e.peer))
        self.seed = seed
        self.n_steps = n_steps

    # -- generation ----------------------------------------------------
    @classmethod
    def generate(cls, seed: int, peers: Sequence[str],
                 n_steps: int = 30, n_faults: int = 6,
                 heal_after: int = 3,
                 kinds: Sequence[str] = ("kill", "partition",
                                         "corrupt", "stall",
                                         "bandwidth", "delay_ack"),
                 ) -> "FaultSchedule":
        """Deterministically draw ``n_faults`` faults over ``n_steps``
        schedule steps. Every fault gets its matching heal
        ``heal_after`` steps later (revive for kill, heal/reset for
        the injected flags), so the fleet always converges back to
        healthy — a drill must end in a repairable state to assert
        repair. Cycling through ``kinds`` before redrawing guarantees
        coverage of every requested kind when ``n_faults >=
        len(kinds)``."""
        if not peers:
            raise ValueError("need at least one peer")
        rng = random.Random(seed)
        events: List[FaultEvent] = []
        # spread fault start steps over the schedule, leaving room for
        # the final heal to land inside it
        last_start = max(n_steps - heal_after - 1, 1)
        for i in range(n_faults):
            kind = kinds[i % len(kinds)]
            peer = rng.choice(list(peers))
            step = rng.randint(1, last_start)
            if kind == "kill":
                events.append(FaultEvent(step, "kill", peer))
                events.append(FaultEvent(step + heal_after, "revive",
                                         peer))
            elif kind == "bandwidth":
                bps = rng.choice([2_000_000.0, 4_000_000.0])
                events.append(FaultEvent(step, "bandwidth", peer,
                                         {"bps": bps}))
                events.append(FaultEvent(step + heal_after,
                                         "bandwidth", peer,
                                         {"bps": None}))
            elif kind == "corrupt":
                events.append(FaultEvent(step, "corrupt", peer,
                                         {"chunks": rng.randint(1, 3)}))
                events.append(FaultEvent(step + heal_after, "heal",
                                         peer))
            elif kind == "stall":
                events.append(FaultEvent(
                    step, "stall", peer,
                    {"seconds": round(rng.uniform(0.05, 0.2), 3)}))
                events.append(FaultEvent(step + heal_after, "heal",
                                         peer))
            elif kind == "delay_ack":
                events.append(FaultEvent(
                    step, "delay_ack", peer,
                    {"seconds": round(rng.uniform(0.05, 0.15), 3)}))
                events.append(FaultEvent(step + heal_after, "heal",
                                         peer))
            elif kind == "partition":
                events.append(FaultEvent(step, "partition", peer))
                events.append(FaultEvent(step + heal_after, "heal",
                                         peer))
            else:
                raise ValueError(f"unknown fault kind {kind!r}")
        return cls(events, seed=seed, n_steps=n_steps)

    # -- replay fingerprint --------------------------------------------
    def event_order(self) -> List[str]:
        """Canonical ordered fingerprint — two schedules replay the
        same chaos iff their event_order()s are equal."""
        return [e.fingerprint() for e in self.events]

    def at(self, step: int) -> List[FaultEvent]:
        return [e for e in self.events if e.step == step]

    def faults(self) -> List[FaultEvent]:
        """Only the degrading events (heals/revives excluded)."""
        return [e for e in self.events
                if e.kind not in ("revive", "heal")
                and not (e.kind == "bandwidth"
                         and e.args.get("bps") is None)]

    # -- (de)serialization --------------------------------------------
    def to_json(self) -> str:
        return json.dumps({"seed": self.seed, "n_steps": self.n_steps,
                           "events": [asdict(e) for e in self.events]},
                          indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "FaultSchedule":
        doc = json.loads(text)
        return cls([FaultEvent(int(e["step"]), e["kind"], e["peer"],
                               dict(e.get("args", {})))
                    for e in doc["events"]],
                   seed=int(doc.get("seed", 0)),
                   n_steps=int(doc.get("n_steps", 0)))
