from repro.data.tokenizer import WordHashTokenizer  # noqa: F401
from repro.data.mmlu import MMLUGenerator, MMLU_DOMAINS  # noqa: F401
