"""Synthetic MMLU-style prompt generator (paper §5.1).

Reproduces the *structure* that the paper's evaluation relies on: 57
domains; within a domain every prompt shares the instruction and the
few-shot examples, while the target question varies. Text is generated
from seeded word pools, so runs are fully deterministic and offline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.core.segments import PromptSegments
from repro.data.tokenizer import WordHashTokenizer

MMLU_DOMAINS = [
    "abstract_algebra", "anatomy", "astronomy", "business_ethics",
    "clinical_knowledge", "college_biology", "college_chemistry",
    "college_computer_science", "college_mathematics", "college_medicine",
    "college_physics", "computer_security", "conceptual_physics",
    "econometrics", "electrical_engineering", "elementary_mathematics",
    "formal_logic", "global_facts", "high_school_biology",
    "high_school_chemistry", "high_school_computer_science",
    "high_school_european_history", "high_school_geography",
    "high_school_government_and_politics", "high_school_macroeconomics",
    "high_school_mathematics", "high_school_microeconomics",
    "high_school_physics", "high_school_psychology",
    "high_school_statistics", "high_school_us_history",
    "high_school_world_history", "human_aging", "human_sexuality",
    "international_law", "jurisprudence", "logical_fallacies",
    "machine_learning", "management", "marketing", "medical_genetics",
    "miscellaneous", "moral_disputes", "moral_scenarios", "nutrition",
    "philosophy", "prehistory", "professional_accounting",
    "professional_law", "professional_medicine", "professional_psychology",
    "public_relations", "security_studies", "sociology",
    "us_foreign_policy", "virology", "world_religions",
]

_WORDS = ("the of and to in is that it for on with as are this be at or "
          "from by not have but they which one all were when we there can "
          "an your what some other than then now only its over also after "
          "first two new more these may like most between state value "
          "system theory model result method problem answer question "
          "number function energy force field matter space time light "
          "cell gene protein market price cost law court right duty").split()


@dataclass
class MMLUPrompt:
    domain: str
    segments: PromptSegments
    instruction_len: int
    example_lens: List[int]
    answer: str


class MMLUGenerator:
    def __init__(self, tokenizer: WordHashTokenizer, n_shot: int = 5,
                 seed: int = 0, question_words: tuple = (24, 48),
                 example_words: tuple = (24, 48)):
        self.tok = tokenizer
        self.n_shot = n_shot
        self.seed = seed
        self.qw = question_words
        self.ew = example_words

    def _sentence(self, rng, lo, hi) -> str:
        n = int(rng.integers(lo, hi + 1))
        return " ".join(rng.choice(_WORDS) for _ in range(n))

    def _domain_rng(self, domain: str):
        return np.random.default_rng(
            (hash(domain) ^ self.seed) & 0x7FFFFFFF)

    def instruction(self, domain: str) -> str:
        return (f"The following are multiple choice questions with answers "
                f"about {domain.replace('_', ' ')} . Choose A B C or D .")

    def examples(self, domain: str) -> List[str]:
        rng = self._domain_rng(domain)
        out = []
        for i in range(self.n_shot):
            q = self._sentence(rng, *self.ew)
            a = rng.choice(["A", "B", "C", "D"])
            out.append(f"Question : {q} ? Answer : {a} .")
        return out

    def prompt(self, domain: str, question_idx: int) -> MMLUPrompt:
        rng = np.random.default_rng(
            (hash((domain, question_idx)) ^ self.seed) & 0x7FFFFFFF)
        instr_ids = self.tok.encode(self.instruction(domain))
        ex_ids = [self.tok.encode(e, bos=False)
                  for e in self.examples(domain)]
        q = self._sentence(rng, *self.qw)
        q_ids = self.tok.encode(f"Question : {q} ? Answer :", bos=False)
        token_ids = list(instr_ids)
        example_lens = []
        for e in ex_ids:
            token_ids.extend(e)
            example_lens.append(len(e))
        token_ids.extend(q_ids)
        seg = PromptSegments.mmlu_style(token_ids, len(instr_ids),
                                        example_lens)
        return MMLUPrompt(domain=domain, segments=seg,
                          instruction_len=len(instr_ids),
                          example_lens=example_lens,
                          answer=str(rng.choice(list("ABCD"))))

    def stream(self, n_prompts: int, domains: Sequence[str] = None):
        """Round-robin over domains — the paper's 6434-prompt workload."""
        domains = list(domains or MMLU_DOMAINS)
        for i in range(n_prompts):
            yield self.prompt(domains[i % len(domains)], i // len(domains))
