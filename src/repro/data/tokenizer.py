"""Deterministic offline tokenizer.

No network, no vocab files: words map to stable ids via blake2s. The
mapping is injective enough for cache-key purposes (the paper's key is a
hash over token ids — identical text must produce identical ids, which
this guarantees) and reserves low ids for special tokens.
"""
from __future__ import annotations

import hashlib
import re
from typing import List

_WORD_RE = re.compile(r"\w+|[^\w\s]")


class WordHashTokenizer:
    PAD, BOS, EOS = 0, 1, 2
    N_SPECIAL = 16

    def __init__(self, vocab: int):
        assert vocab > self.N_SPECIAL * 2
        self.vocab = vocab

    def _word_id(self, w: str) -> int:
        h = hashlib.blake2s(w.lower().encode(), digest_size=4).digest()
        span = self.vocab - self.N_SPECIAL
        return self.N_SPECIAL + int.from_bytes(h, "little") % span

    def encode(self, text: str, bos: bool = True) -> List[int]:
        ids = [self._word_id(w) for w in _WORD_RE.findall(text)]
        return ([self.BOS] if bos else []) + ids

    def encode_words(self, n_words_text: str) -> List[int]:
        return self.encode(n_words_text, bos=False)

    def decode(self, ids) -> str:           # lossy (hash ids)
        return " ".join(f"<{int(i)}>" for i in ids)
