from repro.roofline.hw import V5E  # noqa: F401
from repro.roofline.analysis import (  # noqa: F401
    collective_bytes, cost_summary, roofline_terms)
