"""Target hardware constants (TPU v5e), per the assignment brief."""
from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    name: str
    peak_flops_bf16: float
    hbm_bw: float
    ici_link_bw: float
    hbm_bytes: float


V5E = Chip(
    name="tpu-v5e",
    peak_flops_bf16=197e12,     # 197 TFLOP/s bf16
    hbm_bw=819e9,               # 819 GB/s
    ici_link_bw=50e9,           # ~50 GB/s per link
    hbm_bytes=16 * 2**30,       # 16 GiB
)
