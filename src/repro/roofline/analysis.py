"""Roofline terms from compiled dry-run artifacts.

  compute    = HLO_FLOPs   / (chips * peak_FLOP/s)
  memory     = HLO_bytes   / (chips * HBM_bw)
  collective = coll_bytes  / (chips * link_bw)

``cost_analysis`` supplies FLOPs/bytes. Collective bytes are parsed from
the optimized HLO: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute contributes the byte size of its operands
(resolved via a symbol table of all HLO value definitions).

CAVEAT (verified empirically): XLA counts a ``while`` body ONCE, so any
scan-over-layers contribution must be depth-extrapolated — the dry-run
lowers unrolled L=1 / L=2 variants and solves cost(L) = a + b*L.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w\.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\(", re.M)
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, per_kind: bool = False):
    """Sum operand bytes over every collective op in the (optimized) HLO."""
    sizes: Dict[str, int] = {}
    kinds: Dict[str, int] = {}
    ops = []
    for m in _DEF_RE.finditer(hlo_text):
        name, type_str, opname = m.group(1), m.group(2), m.group(3)
        sizes[name.lstrip("%")] = _type_bytes(type_str)
        for c in _COLLECTIVES:
            if opname == c or opname == c + "-start":
                # operand list: text after '(' up to matching ')'
                line = hlo_text[m.start():hlo_text.find("\n", m.start())]
                args = line[line.find("(") + 1:]
                ops.append((c, args, name.lstrip("%")))
                break
    total = 0
    for kind, args, _ in ops:
        b = 0
        for a in re.finditer(r"%?([\w\.\-]+)", args.split("),")[0]):
            nm = a.group(1)
            if nm in sizes:
                b += sizes[nm]
        total += b
        kinds[kind] = kinds.get(kind, 0) + b
    return (total, kinds) if per_kind else total


def cost_summary(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):       # older jax returns [dict]
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }


def roofline_terms(flops: float, bytes_: float, coll_bytes: float,
                   n_chips: int, chip) -> Dict[str, float]:
    """All three terms in seconds. HLO flops/bytes from cost_analysis are
    *per-program* (per-device in SPMD), so they are divided by one chip's
    rate; collective bytes likewise are per-device program traffic."""
    compute = flops / chip.peak_flops_bf16
    memory = bytes_ / chip.hbm_bw
    collective = coll_bytes / chip.ici_link_bw
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
    }


def extrapolate_depth(c1: Dict[str, float], c2: Dict[str, float],
                      n_layers: int) -> Dict[str, float]:
    """Solve cost(L) = a + b*L from L=1 and L=2 lowers; evaluate at depth."""
    out = {}
    for k in ("flops", "bytes", "coll_bytes"):
        b = c2[k] - c1[k]
        a = c1[k] - b
        out[k] = max(a + b * n_layers, 0.0)
    return out
