"""repro: distributed prompt caching for LLM serving, in JAX.

The paper (Matsutani et al.) as a multi-pod framework: see README.md.
"""
__version__ = "1.0.0"
