"""AdamW with optional low-precision moments (no optax dependency).

``moment_dtype=jnp.bfloat16`` halves optimizer memory — required for the
deepseek-v3-671b dry-run to fit 512 x 16 GB (see DESIGN.md §5).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jnp.ndarray
    mu: any
    nu: any


class Optimizer(NamedTuple):
    init: any
    update: any


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          grad_clip: Optional[float] = 1.0, moment_dtype=None,
          warmup_steps: int = 100) -> Optimizer:

    def schedule(step):
        warm = jnp.minimum(step / max(warmup_steps, 1), 1.0)
        return lr * warm

    def init(params):
        def zeros_like(p):
            dt = moment_dtype or p.dtype
            return jnp.zeros(p.shape, dt)
        return AdamWState(
            count=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros_like, params),
            nu=jax.tree.map(zeros_like, params),
        )

    def update(grads, state, params):
        count = state.count + 1
        if grad_clip is not None:
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)) + 1e-12)
            scale = jnp.minimum(1.0, grad_clip / gnorm)
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
            vf = v.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
            mhat = mf / (1 - b1 ** count)
            vhat = vf / (1 - b2 ** count)
            step_ = schedule(count) * (mhat / (jnp.sqrt(vhat) + eps)
                                       + weight_decay * p.astype(jnp.float32))
            return ((p.astype(jnp.float32) - step_).astype(p.dtype),
                    mf.astype(m.dtype), vf.astype(v.dtype))

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = jax.tree.leaves(state.mu)
        flat_v = jax.tree.leaves(state.nu)
        flat_p = jax.tree.leaves(params)
        outs = [upd(g, m, v, p)
                for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
        return new_p, AdamWState(count, new_m, new_v)

    return Optimizer(init=init, update=update)
