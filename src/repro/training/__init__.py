from repro.training.optimizer import adamw  # noqa: F401
from repro.training.train_step import make_train_step  # noqa: F401
