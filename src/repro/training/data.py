"""Training data pipeline: LM batches from the synthetic MMLU stream."""
from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.data.mmlu import MMLUGenerator
from repro.data.tokenizer import WordHashTokenizer


def lm_batches(cfg, batch: int, seq: int, seed: int = 0,
               n_shot: int = 2) -> Iterator[dict]:
    """Packs MMLU-style prompts into fixed [B, S] next-token batches."""
    tok = WordHashTokenizer(cfg.vocab)
    gen = MMLUGenerator(tok, n_shot=n_shot, seed=seed)
    stream = gen.stream(10 ** 9)
    buf: list = []
    while True:
        rows = []
        while len(rows) < batch:
            while len(buf) < seq + 1:
                buf.extend(next(stream).segments.token_ids)
                buf.append(tok.EOS)
            rows.append(buf[:seq + 1])
            buf = buf[seq + 1:]
        arr = np.asarray(rows, np.int32)
        yield {"tokens": arr[:, :-1], "targets": arr[:, 1:]}
