"""Training step factory: loss + grads + optimizer update, jit/pjit-able."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_train_step(model, optimizer):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        new_params, new_state = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["grad_norm"] = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)) + 1e-12)
        return new_params, new_state, metrics
    return train_step


def make_eval_step(model):
    def eval_step(params, batch):
        _, metrics = model.loss(params, batch)
        return metrics
    return eval_step
