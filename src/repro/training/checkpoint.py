"""Checkpointing: msgpack + compression of a flattened pytree (no orbax).

Uses the shared codec-tagged framing from ``core/state_io`` (zstd when
the optional ``[edge]`` extra is installed, stdlib zlib otherwise), so
checkpoints stay readable/writable on a bare interpreter.
"""
from __future__ import annotations

import os
from typing import Any, Tuple

import msgpack
import numpy as np

import jax
import jax.numpy as jnp

from repro.core.state_io import _compress, _decompress


def _paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


def save(path: str, tree: Any, step: int = 0) -> None:
    keys, leaves, _ = _paths(tree)
    payload = {
        "step": step,
        "leaves": [{
            "path": k,
            "shape": list(np.shape(l)),
            "dtype": str(np.asarray(l).dtype),
            "data": np.ascontiguousarray(np.asarray(l)).tobytes(),
        } for k, l in zip(keys, leaves)],
    }
    raw = _compress(msgpack.packb(payload, use_bin_type=True),
                    codec="auto", level=3)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(raw)
    os.replace(tmp, path)


def load(path: str, template: Any) -> Tuple[Any, int]:
    with open(path, "rb") as f:
        raw = f.read()
    try:
        body = _decompress(raw)
    except ValueError:
        # legacy checkpoints (pre codec tags) are a bare zstd stream
        import zstandard as zstd
        body = zstd.ZstdDecompressor().decompress(raw)
    payload = msgpack.unpackb(body, raw=False)
    stored = {d["path"]: d for d in payload["leaves"]}
    keys, leaves, treedef = _paths(template)
    new = []
    for k, l in zip(keys, leaves):
        d = stored.get(k)
        if d is None:
            raise ValueError(f"checkpoint missing leaf {k}")
        arr = np.frombuffer(d["data"], dtype=d["dtype"]).reshape(d["shape"])
        if tuple(arr.shape) != tuple(np.shape(l)):
            raise ValueError(f"shape mismatch for {k}")
        new.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, new), payload["step"]
