"""Beyond-paper (CacheGen-adjacent, [8] in the paper): int8 prompt-cache
blobs. Measures blob-size reduction and the resulting TTFT-hit change on
the low-end setting, plus greedy-output fidelity."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, make_world
from repro.config import CacheConfig
from repro.core import EdgeClient
from repro.core.transport import InProcTransport
from repro.serving.engine import InferenceEngine
from repro.data import MMLU_DOMAINS


def main():
    w = make_world("low")
    sizes = {}
    outputs = {}
    for mode, quant in (("fp", False), ("int8", True)):
        w.server.__init__(CacheConfig(quantize=quant))
        ccfg = CacheConfig(quantize=quant)

        def client(name):
            eng = InferenceEngine(w.model, w.params, max_len=1024)
            tr = InProcTransport(w.server, w.net, w.clock)
            return EdgeClient(name, eng, tr, ccfg, perf=w.perf,
                              perf_cfg=w.cfg)
        c1, c2 = client("a"), client("b")
        blob_bytes, hit_ttft, outs = [], [], []
        for p in w.gen.stream(6, MMLU_DOMAINS[:6]):
            r1 = c1.infer(p.segments, max_new_tokens=8)
            c2.sync_catalog()
            c2.catalog.last_sync_t = -1e18
            r2 = c2.infer(p.segments, max_new_tokens=8)
            blob_bytes.append(r2.blob_bytes_down)
            outs.append((r1.output_tokens, r2.output_tokens))
            hit_ttft.append(r2.sim.ttft)
        sizes[mode] = float(np.mean(blob_bytes))
        outputs[mode] = outs

    fidelity = sum(a == b for a, b in outputs["int8"]) / len(
        outputs["int8"])
    return [csv_line(
        "quantized_blobs", sizes["int8"],
        f"fp_bytes={sizes['fp']:.0f};int8_bytes={sizes['int8']:.0f};"
        f"ratio={sizes['int8'] / sizes['fp']:.2f};"
        f"hit_vs_miss_output_match={fidelity:.2f}")]


if __name__ == "__main__":
    main()
