"""Chunked state-blob pipeline (wire format v3): measured wall-clock wins.

Three claims, each asserted here so CI pins them:

1. **Single-pass range uploads** — a miss with ``max_ranges=R`` costs
   ONE serialization pass (``extract_state_ranges``), not R: the
   longest range is chunked at the range boundaries and every shorter
   range is a header rewrite over shared chunk bytes.
2. **Real download/compute overlap** — on a partial hit over a
   bandwidth-constrained link (a real TCP socket, server paced to the
   measured suffix-prefill speed), the layer-streamed client's **wall**
   TTFT is >= 30% below the single-frame v2 path, with token-identical
   outputs vs both the v2 path and cache-off.
3. **Mixed-version fleet** — a v3 streaming client against a peer
   holding v2 single-frame blobs still restores and stays
   token-identical (the compat guarantee for already-stored blobs).

Emits ``BENCH_blob_pipeline.json`` (serialize/restore MB/s, overlap
hidden fraction, TTFT numbers) so the perf trajectory has data points.

    PYTHONPATH=src python -m benchmarks.blob_pipeline [--quick]
"""
from __future__ import annotations

import sys
import time

import jax

from benchmarks.common import csv_line, write_bench
from repro.config import CacheConfig
from repro.configs import get_config
from repro.core import CacheServer, EdgeClient, state_io
from repro.core.keys import model_meta
from repro.core.net.server import serve_peer_tcp
from repro.core.transport import TCPTransport
from repro.data import MMLUGenerator, WordHashTokenizer
from repro.models import Model
from repro.serving.engine import InferenceEngine


def build_world():
    """An executable model big enough that suffix prefill costs real
    wall time (the overlap drill needs compute to hide)."""
    cfg = get_config("gemma3-270m").reduced().replace(
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=2, head_dim=64,
        d_ff=1024, vocab=2048)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = InferenceEngine(model, params, max_len=1024)
    gen = MMLUGenerator(WordHashTokenizer(cfg.vocab), n_shot=6,
                        question_words=(150, 180),
                        example_words=(60, 80))
    return cfg, model, params, engine, gen


def serialize_micro(model, engine, meta, lines, out):
    """Single-pass multi-range serialization vs R x extract_state."""
    import numpy as np
    rng = np.random.default_rng(0)
    toks = rng.integers(3, model.cfg.vocab, (1, 512)).astype(np.int32)
    st = engine.start({"tokens": toks})
    n_effs = [model.cache_len(n) for n in (128, 256, 384, 512)]

    state_io.STATS["serialize_passes"] = 0
    t0 = time.perf_counter()
    chunk_lists = state_io.extract_state_ranges(
        st.cache, n_effs, meta, logits=st.last_logits)
    t_v3 = time.perf_counter() - t0
    passes = state_io.STATS["serialize_passes"]
    assert passes == 1, \
        f"multi-range serialization took {passes} passes, expected 1"
    containers = {n: state_io.pack_container(c)
                  for n, c in chunk_lists.items()}
    total_bytes = sum(len(b) for b in containers.values())

    t0 = time.perf_counter()
    for n_eff in n_effs:
        state_io.extract_state(
            st.cache, n_eff, meta,
            logits=st.last_logits if n_eff == n_effs[-1] else None)
    t_v2 = time.perf_counter() - t0

    # restore throughput through the chunked path
    big = containers[n_effs[-1]]
    template = engine.new_cache()
    t0 = time.perf_counter()
    payload = state_io.parse_state(big, meta)
    cache, n_eff, logits = state_io.restore_state(payload, template)
    jax.block_until_ready(jax.tree_util.tree_leaves(cache)[0])
    t_restore = time.perf_counter() - t0

    ser_mbps = total_bytes / 1e6 / t_v3
    rest_mbps = len(big) / 1e6 / t_restore
    out["serialize_MBps"] = round(ser_mbps, 1)
    out["restore_MBps"] = round(rest_mbps, 1)
    out["serialize_passes"] = passes
    out["single_pass_speedup"] = round(t_v2 / t_v3, 2)
    lines.append(csv_line(
        "blob_pipeline_serialize", t_v3 * 1e6,
        f"ranges={len(n_effs)};passes=1;bytes={total_bytes};"
        f"MBps={ser_mbps:.1f};vs_v2_xR={t_v2 / t_v3:.2f}x;"
        f"restore_MBps={rest_mbps:.1f}"))
    return st


def overlap_drill(engine, gen, lines, out, quick=False):
    """Wall-clock TTFT, partial hit, constrained link: v2 single-frame
    vs v3 layer-streamed, plus the mixed-version compat check."""
    server = CacheServer(CacheConfig())
    srv = serve_peer_tcp(server)

    def link():
        return TCPTransport("127.0.0.1", srv.port, timeout=120.0)

    def client(name, overlap):
        return EdgeClient(name, engine, link(), CacheConfig(),
                          overlap=overlap)

    # seed: one prompt's ranges uploaded; a sibling prompt (same
    # instruction+examples prefix, different question) partial-hits
    seed = client("seed", False)
    p0 = gen.prompt("anatomy", 0)
    seed.infer(p0.segments, max_new_tokens=2)
    p1 = gen.prompt("anatomy", 1)
    hit_key = next(k for k in p1.segments.keys(seed.meta)
                   if k.digest in server.store)
    blob_bytes = len(server.store[hit_key.digest])

    # anchors + jit warmup (both paths compile off the clock)
    off = client("off", False)
    ref = off.infer(p1.segments, max_new_tokens=4, upload_on_miss=False)
    assert ref.matched_tokens == 0
    c_v2, c_v3 = client("v2", False), client("v3", True)
    for c in (c_v2, c_v3):
        c.sync_catalog()
        warm = c.infer(p1.segments, max_new_tokens=4,
                       upload_on_miss=False)
        assert warm.matched_tokens == hit_key.n_tokens
        assert warm.output_tokens == ref.output_tokens
    # steady-state suffix prefill: the compute the stream must hide
    # (min of two runs — one slow calibration sample would mis-set the
    # link and squeeze the measured win)
    prefill_s = max(min(
        c_v2.infer(p1.segments, max_new_tokens=4,
                   upload_on_miss=False).wall.p_decode
        for _ in range(2)), 0.02)
    # constrain the link so transfer ~= suffix prefill — the pipelined
    # regime where hiding compute behind the stream pays the most
    srv.throttle_bps = blob_bytes * 8.0 / prefill_s

    def best_of(c, n):
        best = None
        for _ in range(n):
            r = c.infer(p1.segments, max_new_tokens=4,
                        upload_on_miss=False)
            assert r.matched_tokens == hit_key.n_tokens
            assert r.output_tokens == ref.output_tokens, \
                "overlap drill: outputs diverged from cache-off"
            if best is None or r.wall.ttft < best[0]:
                best = (r.wall.ttft, r)
        return best

    n_runs = 3 if quick else 4
    t_v2 = t_v3 = r_v2 = r_v3 = reduction = None
    for attempt in range(3):
        t_v2, r_v2 = best_of(c_v2, n_runs)
        t_v3, r_v3 = best_of(c_v3, n_runs)
        reduction = 1.0 - t_v3 / t_v2
        if reduction >= 0.30:
            break
        # a loaded machine can eat the margin on one sample set;
        # re-measure (bounded) before declaring the floor breached
    hidden = r_v3.extra.get("overlap_hidden_s", 0.0)
    chunks = int(r_v3.extra.get("chunks_down", 0))
    assert chunks > 2, "v3 client did not stream chunks"
    assert reduction >= 0.30, (
        f"chunked overlap saved only {100 * reduction:.1f}% wall TTFT "
        f"(v2 {t_v2:.3f}s -> v3 {t_v3:.3f}s); acceptance floor is 30%")
    out["ttft_v2_s"] = round(t_v2, 4)
    out["ttft_v3_s"] = round(t_v3, 4)
    out["wall_ttft_reduction_pct"] = round(100 * reduction, 1)
    out["overlap_hidden_frac"] = round(hidden / t_v2, 3)
    out["stream_chunks"] = chunks
    out["blob_bytes"] = blob_bytes
    out["link_mbps"] = round(srv.throttle_bps / 1e6, 1)
    lines.append(csv_line(
        "blob_pipeline_overlap", t_v3 * 1e6,
        f"link={srv.throttle_bps / 1e6:.1f}Mb/s;blob={blob_bytes};"
        f"ttft_v2={t_v2:.3f}s;ttft_v3={t_v3:.3f}s;"
        f"reduction={100 * reduction:.1f}%;hidden={hidden:.3f}s;"
        f"chunks={chunks};tokens_identical=True"))

    # mixed-version fleet: overwrite the hit blob with a v2
    # single-frame blob — the v3 streaming client must restore it
    # byte-identically through the same get_chunks path
    meta = c_v3.meta
    payload = state_io.parse_state(server.store[hit_key.digest], meta)
    cache, n_eff, _ = state_io.restore_state(payload, engine.new_cache())
    v2_blob = state_io.extract_state(cache, n_eff, meta)
    server.store[hit_key.digest] = v2_blob
    server.stored_bytes += len(v2_blob) - blob_bytes
    r_mix = c_v3.infer(p1.segments, max_new_tokens=4,
                       upload_on_miss=False)
    assert r_mix.matched_tokens == hit_key.n_tokens
    assert r_mix.output_tokens == ref.output_tokens, \
        "mixed-version fleet: v2 blob through v3 client diverged"
    out["v2_compat_tokens_identical"] = True
    lines.append(csv_line(
        "blob_pipeline_v2_compat", r_mix.wall.ttft * 1e6,
        f"v2_blob_via_get_chunks=ok;matched={r_mix.matched_tokens};"
        f"tokens_identical=True"))
    srv.close()


def main():
    quick = "--quick" in sys.argv
    cfg, model, params, engine, gen = build_world()
    meta = model_meta(cfg, "float32")
    lines, out = [], {}
    serialize_micro(model, engine, meta, lines, out)
    overlap_drill(engine, gen, lines, out, quick=quick)
    write_bench("BENCH_blob_pipeline.json", out)
    return lines


if __name__ == "__main__":
    main()
