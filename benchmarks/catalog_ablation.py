"""Paper §5.2.3: benefit of the local catalog. Under a 0%-hit workload,
clients WITHOUT a catalog pay a server round-trip per range probe on every
request; clients WITH a catalog never touch the network."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, make_world
from repro.data import MMLU_DOMAINS


def main():
    w = make_world("low")
    with_cat = w.client("with", use_catalog=True)
    without = w.client("without", use_catalog=False)
    t_with, t_without = [], []
    for p in w.gen.stream(12, MMLU_DOMAINS[8:12]):
        r1 = with_cat.infer(p.segments, max_new_tokens=2,
                            upload_on_miss=False)
        r2 = without.infer(p.segments, max_new_tokens=2,
                           upload_on_miss=False)
        t_with.append(r1.sim.ttft)
        t_without.append(r2.sim.ttft)
    a, b = float(np.mean(t_with)), float(np.mean(t_without))
    return [csv_line(
        "catalog_ablation_cold_ttft", a * 1e6,
        f"with_catalog={a:.3f}s;without={b:.3f}s;"
        f"overhead_avoided={(b - a) * 1e3:.1f}ms;"
        f"catalog_size_MB={with_cat.catalog.size_bytes / 1e6:.2f}")]


if __name__ == "__main__":
    main()
