"""Serving-layer throughput: batch-size x hit-rate sweep.

Two sweeps over the reduced Gemma-3 270M executable model:

* **Batch sweep** — the continuous-batching Scheduler over a
  ``BatchedEngine`` pool of B slots, one fixed request set. Reports
  aggregate generated tokens/sec and TTFT percentiles per B. The B=4
  vs B=1 ratio is the headline number (>=2x expected: every decode
  iteration advances B slots for ~one slot's dispatch cost).

* **Hit-rate sweep** — a 4-session ``SessionPool`` against one
  CacheServer where a fraction of the request stream shares an
  already-cached prefix. Reports simulated mean TTFT, server GETs and
  broker dedup counts per hit rate — the cache-sharing side of the
  same multi-user story.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import csv_line, make_world
from repro.config import CacheConfig
from repro.core import Fabric, SessionPool
from repro.serving import BatchedEngine, Request, Scheduler


def bench_batch_sweep(w, batch_sizes, n_requests, prompt_len, max_new,
                      lines):
    rng = np.random.default_rng(0)
    prompts = [rng.integers(3, w.exec_cfg.vocab,
                            (prompt_len,)).astype(np.int32)
               for _ in range(n_requests)]
    base = None
    for b in batch_sizes:
        eng = BatchedEngine(w.model, w.params, max_len=512, batch_size=b)
        # warm the compile caches off the clock, then recycle the slots
        warm = Scheduler(eng)
        warm.run([Request(tokens=prompts[0], max_new_tokens=2)
                  for _ in range(b + 1)])
        eng.pos[:] = 0
        sched = Scheduler(eng)
        sched.run([Request(tokens=p, max_new_tokens=max_new)
                   for p in prompts])
        rep = sched.report()
        if b == batch_sizes[0]:
            base = rep.throughput_tok_s
        lines.append(csv_line(
            f"serving_batch{b}", rep.wall_s / max(rep.n_requests, 1) * 1e6,
            f"tok_per_s={rep.throughput_tok_s:.1f};"
            f"ttft_p50_ms={rep.ttft_p50 * 1e3:.1f};"
            f"ttft_p99_ms={rep.ttft_p99 * 1e3:.1f};"
            f"speedup_vs_b{batch_sizes[0]}="
            f"{rep.throughput_tok_s / base:.2f}x"))
    return lines


def bench_hit_rate_sweep(w, hit_rates, n_requests, max_new, lines):
    domains = ["astronomy", "virology", "marketing", "nutrition"]
    for hr in hit_rates:
        w2 = make_world("low")          # fresh server per point
        # seed the server: one client uploads each domain's shared prefix
        seeder = w2.client("seeder")
        for d in domains:
            seeder.infer(w2.gen.prompt(d, 0).segments, max_new_tokens=1)
        fabric = Fabric.local(CacheConfig(), net=w2.net,
                              server=w2.server)
        pool = SessionPool(engine=seeder.engine, fabric=fabric,
                           n_sessions=4, cache_cfg=CacheConfig(),
                           perf=w2.perf, perf_cfg=w2.cfg)
        pool.sync_catalogs()
        rng = np.random.default_rng(1)
        jobs = []
        for i in range(n_requests):
            if rng.random() < hr:       # shares a seeded domain prefix
                jobs.append(w2.gen.prompt(domains[i % len(domains)],
                                          1 + i).segments)
            else:                       # cold domain -> miss
                jobs.append(w2.gen.prompt("prehistory",
                                          1000 + i).segments)
        g0 = w2.server.handle("stats", {})["stats"]["gets"]
        # upload_on_miss=False: keep the hit rate pinned to the seeded
        # prefixes instead of letting the stream populate the cache
        res = pool.run(jobs, max_new_tokens=max_new,
                       upload_on_miss=False)
        g1 = w2.server.handle("stats", {})["stats"]["gets"]
        ttft = float(np.mean([r.sim.ttft for r in res]))
        hits = sum(r.matched_tokens > 0 for r in res)
        lines.append(csv_line(
            f"serving_hitrate{int(hr * 100)}", ttft * 1e6,
            f"sim_ttft_s={ttft:.3f};hits={hits}/{len(res)};"
            f"server_gets={g1 - g0};"
            f"broker_joined={pool.broker.stats['joined']};"
            f"broker_cached={pool.broker.stats['cache_hits']}"))
    return lines


def main(quick: bool = False):
    w = make_world("low")
    lines = []
    batch_sizes = (1, 2, 4) if quick else (1, 2, 4, 8)
    n_req = 8 if quick else 16
    max_new = 16 if quick else 32
    bench_batch_sweep(w, batch_sizes, n_req, prompt_len=96,
                      max_new=max_new, lines=lines)
    bench_hit_rate_sweep(w, (0.0, 0.5, 1.0), n_requests=8 if quick else 16,
                         max_new=2, lines=lines)
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes")
    main(quick=ap.parse_args().quick)
