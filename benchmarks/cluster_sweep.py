"""Multi-peer cache fabric vs the paper's single cache box.

Sweeps N peers x link heterogeneity x workload skew on an MMLU-style
workload, holding TOTAL store bytes equal between the fabric and the
single-server baseline (each of N peers gets budget/N). Three runs per
configuration share one prompt sequence:

  * cache-off     — every prompt prefills locally (correctness anchor)
  * single-server — the paper's star topology over the default Wi-Fi link
  * multi-peer    — consistent-hash placement, gossip-synced per-peer
                    catalogs, link-aware fetch planning, hot-key
                    replication onto the fastest link

Greedy outputs must be token-identical across all three (asserted), and
a fault drill kills one peer mid-run: the workload must complete with no
hang and unchanged tokens (suspect marking + local-prefill fallback).

    PYTHONPATH=src python -m benchmarks.cluster_sweep [--quick]
"""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import csv_line, make_world
from repro.config import CacheConfig
from repro.core import (
    CacheCluster, CacheServer, EdgeClient, SimClock, SimNetwork,
)
from repro.core.metrics import ServingReport
from repro.core.transport import InProcTransport
from repro.serving.engine import InferenceEngine

# per-peer (bandwidth_bps, rtt_s): one fast 5 GHz neighbor, the paper's
# 2.4 GHz Wi-Fi 4 box, and a congested hop
HET_LINKS = [(40e6, 0.002), (21e6, 0.003), (8e6, 0.008)]
UNIFORM_LINKS = [(21e6, 0.003)] * 3
BASELINE_NET = SimNetwork()            # 21 Mb/s — the paper's link


def skewed_workload(gen, n_prompts: int, domains, skew: float,
                    q_pool: int = 3, seed: int = 7):
    """Zipf-over-domains prompt stream with a small per-domain question
    pool, so popular domains repeat prompts (full hits) and unpopular
    ones stay cold — the regime where placement + links matter."""
    rng = np.random.default_rng(seed)
    if skew > 0:
        w = 1.0 / np.arange(1, len(domains) + 1) ** skew
        w /= w.sum()
    prompts = []
    for i in range(n_prompts):
        d = domains[int(rng.choice(len(domains), p=w))] if skew > 0 \
            else domains[i % len(domains)]
        prompts.append(gen.prompt(d, int(rng.integers(q_pool))).segments)
    return prompts


def run_single(engine, w, prompts, ccfg, max_new: int, cache: bool):
    server = CacheServer(ccfg)
    tr = InProcTransport(server, BASELINE_NET, SimClock())
    c = EdgeClient("single", engine, tr, ccfg, perf=w.perf, perf_cfg=w.cfg)
    results = []
    for p in prompts:
        if cache:
            c.catalog.last_sync_t = -1e18
            c.sync_catalog()
        results.append(c.infer(p, max_new_tokens=max_new,
                               upload_on_miss=cache))
    return results, server.stored_bytes


def run_fabric(engine, w, prompts, ccfg, max_new: int, links,
               kill_at: int = -1, kill_peer: str = "",
               adaptive: bool = True, gossip_fanout=None,
               congest_at: int = -1, congest_peer: str = "",
               congest_bw: float = 1e6, overlap: bool = False):
    cluster = CacheCluster(links, ccfg)
    # replicate on first fetch: at most one GET per key ever pays a slow
    # link, then the planner routes over the fastest replica (the store
    # budget is charged identically to the single-server baseline)
    d = cluster.directory(clock=SimClock(), hot_threshold=1,
                          adaptive=adaptive)
    c = EdgeClient("fabric", engine, d, ccfg, perf=w.perf, perf_cfg=w.cfg,
                   overlap=overlap)
    results = []
    for i, p in enumerate(prompts):
        cluster.gossip(fanout=gossip_fanout)
        d.last_sync_t = -1e18
        c.sync_catalog()
        if i == kill_at:
            # kill AFTER the sync so the next GET (not the off-path
            # sync) is what discovers the death — the worst case
            cluster.kill(kill_peer)
        if i == congest_at:
            # silent mid-run congestion: the link's true bandwidth
            # collapses but nothing announces it — only observed
            # transfers can reveal it to the planner
            cluster.by_id[congest_peer].net.bandwidth_bps = congest_bw
        results.append(c.infer(p, max_new_tokens=max_new))
    return results, cluster, d


def mean_ttft(results, hits: bool = None) -> float:
    sel = [r.sim.ttft for r in results
           if hits is None or (r.matched_tokens > 0) == hits]
    return float(np.mean(sel)) if sel else 0.0


def main():
    quick = "--quick" in sys.argv
    from repro.data import MMLU_DOMAINS
    domains = MMLU_DOMAINS[:3]
    n_prompts = 24 if quick else 60
    max_new = 4
    budget_total = 2_000_000            # equal store bytes, both fabrics

    # (name, world setting, links, zipf skew). The "high" rows are the
    # regime where the paper itself measured caching HURTING TTFT
    # (-7.08%): Pi-5-class prefill rivals the blob transfer, so blind
    # longest-first fetching loses and the planner's per-link
    # fetch-vs-recompute pruning is what rescues the fabric.
    sweep = [("low_3het_skew", "low", HET_LINKS, 1.2)]
    if not quick:
        sweep += [("high_3het_skew", "high", HET_LINKS, 1.2),
                  ("low_3het_uniform", "low", HET_LINKS, 0.0),
                  ("low_3uni_skew", "low", UNIFORM_LINKS, 1.2),
                  ("low_5het_skew", "low",
                   HET_LINKS + [(30e6, 0.002), (5e6, 0.012)], 1.2)]

    engines = {}

    def world_engine(setting):
        if setting not in engines:
            w = make_world(setting)
            engines[setting] = (w, InferenceEngine(w.model, w.params,
                                                   max_len=512))
        return engines[setting]

    lines = []
    for name, setting, links, skew in sweep:
        w, engine = world_engine(setting)
        prompts = skewed_workload(w.gen, n_prompts, domains, skew)
        n_peers = len(links)
        ccfg_single = CacheConfig(max_store_bytes=budget_total)
        ccfg_peer = CacheConfig(max_store_bytes=budget_total // n_peers)

        off, _ = run_single(engine, w, prompts, ccfg_single, max_new,
                            cache=False)
        single, single_bytes = run_single(engine, w, prompts, ccfg_single,
                                          max_new, cache=True)
        fabric, cluster, d = run_fabric(engine, w, prompts, ccfg_peer,
                                        max_new, links)

        outs = [r.output_tokens for r in off]
        assert [r.output_tokens for r in single] == outs, \
            f"{name}: single-server outputs diverged"
        assert [r.output_tokens for r in fabric] == outs, \
            f"{name}: multi-peer outputs diverged"

        rep = ServingReport.from_infer_results(fabric,
                                               per_peer=d.peer_stats())
        t_off = mean_ttft(off)
        t_sin, t_fab = mean_ttft(single), mean_ttft(fabric)
        hits = ";".join(f"{pid}:h{st.hits}/m{st.misses}"
                        for pid, st in rep.per_peer.items())
        est_err = sum(st.est_error_s for st in rep.per_peer.values())
        lines.append(csv_line(
            f"cluster_{name}", t_fab * 1e6,
            f"peers={n_peers};ttft_off={t_off:.3f}s;"
            f"ttft_single={t_sin:.3f}s;ttft_fabric={t_fab:.3f}s;"
            f"fabric_vs_single={100 * (1 - t_fab / t_sin):.1f}%;"
            f"hit_ttft_single={mean_ttft(single, hits=True):.3f}s;"
            f"hit_ttft_fabric={mean_ttft(fabric, hits=True):.3f}s;"
            f"p99_fabric={rep.ttft_p99:.3f}s;tokens_identical=True;"
            f"store_single={single_bytes};store_fabric="
            f"{cluster.stored_bytes()};budget={budget_total};"
            f"replications={d.replications};{hits};"
            f"est_err_s={est_err:.3f}"))

    # congestion drill: the fastest link silently collapses to 1 Mb/s a
    # third of the way in. The static planner keeps pricing it from its
    # nominal 40 Mb/s and keeps routing the hot head over it; the
    # adaptive planner reprices from observed transfers (LinkEstimator
    # EWMA) within a few fetches and reroutes to replicas/local prefill.
    name, setting, links, skew = sweep[0]
    w, engine = world_engine(setting)
    prompts = skewed_workload(w.gen, n_prompts, domains, skew)
    ccfg_peer = CacheConfig(max_store_bytes=budget_total // len(links))
    off, _ = run_single(engine, w, prompts,
                        CacheConfig(max_store_bytes=budget_total),
                        max_new, cache=False)
    congest = dict(congest_at=n_prompts // 3, congest_peer="peer0",
                   congest_bw=1e6)
    static, _, _ = run_fabric(engine, w, prompts, ccfg_peer, max_new,
                              links, adaptive=False, **congest)
    adapt, _, d_ad = run_fabric(engine, w, prompts, ccfg_peer, max_new,
                                links, adaptive=True, **congest)
    outs = [r.output_tokens for r in off]
    assert [r.output_tokens for r in static] == outs, \
        "congestion drill: static outputs diverged"
    assert [r.output_tokens for r in adapt] == outs, \
        "congestion drill: adaptive outputs diverged"
    post = slice(n_prompts // 3, None)
    t_static, t_adapt = mean_ttft(static), mean_ttft(adapt)
    t_static_post = mean_ttft(static[post])
    t_adapt_post = mean_ttft(adapt[post])
    assert t_adapt < t_static, (
        f"adaptive planner ({t_adapt:.3f}s) did not beat static "
        f"({t_static:.3f}s) under congestion")
    p0 = d_ad.peer_stats().get("peer0")
    lines.append(csv_line(
        "cluster_congested_adaptive_vs_static", t_adapt * 1e6,
        f"congested=peer0@{n_prompts // 3}->1Mb/s;"
        f"ttft_static={t_static:.3f}s;ttft_adaptive={t_adapt:.3f}s;"
        f"adaptive_vs_static={100 * (1 - t_adapt / t_static):.1f}%;"
        f"post_ttft_static={t_static_post:.3f}s;"
        f"post_ttft_adaptive={t_adapt_post:.3f}s;"
        f"est_bw_peer0={p0.est_bw_bps / 1e6:.1f}Mb/s;"
        f"obs_peer0={p0.link_observations};tokens_identical=True"))

    # overlap drill: a partial-hit-heavy workload (one domain, distinct
    # questions — every prompt after the first shares the
    # instruction+examples prefix) through the layer-streamed client
    # (v3 chunk pipeline) vs the blocking one. The streamed client's
    # chunks arrive through real get_chunks streams, the suffix prefill
    # pipelines against them, and the hidden transfer time comes off
    # the TTFT path — tokens identical throughout.
    name, setting, links, skew = sweep[0]
    w, engine = world_engine(setting)
    ov_prompts = [w.gen.prompt(domains[0], q).segments
                  for q in range(min(n_prompts, 16))]
    ccfg_peer = CacheConfig(max_store_bytes=budget_total // len(links))
    off, _ = run_single(engine, w, ov_prompts,
                        CacheConfig(max_store_bytes=budget_total),
                        max_new, cache=False)
    plain, _, _ = run_fabric(engine, w, ov_prompts, ccfg_peer, max_new,
                             links, overlap=False)
    stream, _, d_ov = run_fabric(engine, w, ov_prompts, ccfg_peer,
                                 max_new, links, overlap=True)
    outs = [r.output_tokens for r in off]
    assert [r.output_tokens for r in plain] == outs, \
        "overlap drill: blocking-client outputs diverged"
    assert [r.output_tokens for r in stream] == outs, \
        "overlap drill: streamed-client outputs diverged"
    hidden = sum(r.extra.get("overlap_hidden_s", 0.0) for r in stream)
    chunks = sum(int(r.extra.get("chunks_down", 0)) for r in stream)
    partials = sum(0 < r.matched_tokens < r.prompt_tokens
                   for r in stream)
    assert partials > 0 and chunks > 0 and hidden > 0, \
        "overlap drill: no layer-streamed partial hits happened"
    t_plain, t_stream = mean_ttft(plain), mean_ttft(stream)
    assert t_stream < t_plain, (
        f"streamed TTFT {t_stream:.3f}s did not beat blocking "
        f"{t_plain:.3f}s")
    peer_hidden = sum(st.overlap_hidden_s
                      for st in d_ov.peer_stats().values())
    lines.append(csv_line(
        "cluster_overlap_drill", t_stream * 1e6,
        f"partial_hits={partials}/{len(ov_prompts)};"
        f"ttft_blocking={t_plain:.3f}s;ttft_streamed={t_stream:.3f}s;"
        f"streamed_vs_blocking={100 * (1 - t_stream / t_plain):.1f}%;"
        f"hidden_s={hidden:.3f};chunks={chunks};"
        f"peer_hidden_s={peer_hidden:.3f};tokens_identical=True"))

    # fault drill: kill the fastest peer halfway through the skewed run,
    # right after a catalog sync — the next GET discovers the death
    name, setting, links, skew = sweep[0]
    w, engine = world_engine(setting)
    prompts = skewed_workload(w.gen, n_prompts, domains, skew)
    ccfg_peer = CacheConfig(max_store_bytes=budget_total // len(links))
    off, _ = run_single(engine, w, prompts,
                        CacheConfig(max_store_bytes=budget_total),
                        max_new, cache=False)
    fabric, cluster, d = run_fabric(
        engine, w, prompts, ccfg_peer, max_new, links,
        kill_at=n_prompts // 2, kill_peer="peer0")
    assert [r.output_tokens for r in fabric] == \
        [r.output_tokens for r in off], "kill drill: outputs diverged"
    dead = sum(int(r.extra.get("dead_peer_failures", 0)) for r in fabric)
    t_fab = mean_ttft(fabric)
    lines.append(csv_line(
        "cluster_kill_drill", t_fab * 1e6,
        f"killed=peer0@{n_prompts // 2};completed={len(fabric)}/"
        f"{n_prompts};dead_fastfails={dead};tokens_identical=True;"
        f"ttft_fabric={t_fab:.3f}s"))

    # repair drill: a peer is killed DURING the upload burst and later
    # revived. Client writes stay a single PUT (replication fan-out and
    # hinted handoff are peer-to-peer, off the client's critical path);
    # once the victim is back, every misplaced key must become readable
    # via its true consistent-hash primary within a bounded number of
    # repair rounds — and outputs stay token-identical to the
    # single-server and cache-off anchors throughout.
    name, setting, links, skew = sweep[0]
    w, engine = world_engine(setting)
    prompts = skewed_workload(w.gen, n_prompts, domains, skew, seed=11)
    ccfg_repair = CacheConfig()         # unbounded: isolate repair from LRU
    off, _ = run_single(engine, w, prompts, ccfg_repair, max_new,
                        cache=False)
    single, _ = run_single(engine, w, prompts, ccfg_repair, max_new,
                           cache=True)
    cluster = CacheCluster(links, ccfg_repair)
    d = cluster.directory(clock=SimClock(), hot_threshold=1)
    c = EdgeClient("repair", engine, d, ccfg_repair, perf=w.perf,
                   perf_cfg=w.cfg)
    kill_at, revive_at = n_prompts // 4, (3 * n_prompts) // 4
    # an upload burst aimed at the victim: keys whose consistent-hash
    # primary IS peer0, shipped while peer0 is down — the exact
    # write-path misplacement scenario (client falls down the ring,
    # fallback acceptor records a hinted handoff)
    import hashlib
    burst, i = [], 0
    while len(burst) < 6:
        dg = hashlib.blake2b(b"repair-%d" % i, digest_size=32).digest()
        if d.placement.primary(dg) == "peer0":
            burst.append(dg)
        i += 1
    results = []
    for i, p in enumerate(prompts):
        if i == kill_at:
            cluster.kill("peer0")
            for dg in burst:            # mid-outage upload burst
                assert d.upload(dg, b"burst" + dg) > 0
        if i == revive_at:
            cluster.revive("peer0")
        cluster.gossip()                # heartbeat: pumps repair pushes
        d.last_sync_t = -1e18
        c.sync_catalog()
        results.append(c.infer(p, max_new_tokens=max_new))
    outs = [r.output_tokens for r in off]
    assert [r.output_tokens for r in single] == outs, \
        "repair drill: single-server outputs diverged"
    assert [r.output_tokens for r in results] == outs, \
        "repair drill: fabric outputs diverged"
    # bounded convergence: a handful of extra rounds must drain every
    # pending push/handoff now that the whole fleet is alive
    repair_rounds = 0
    while cluster.repair_round() and repair_rounds < 8:
        repair_rounds += 1
    assert cluster.repair_round() == 0, \
        "repair drill: replication did not converge"
    # every key is now readable via its TRUE primary — the misplacement
    # bug class (primary probe missing forever) is repaired
    all_keys = {k for p in cluster.peers for k in p.server.store}
    for key in all_keys:
        prim = d.placement.primary(key)
        assert key in cluster.by_id[prim].server.store, \
            "repair drill: key not readable via its primary"
    for dg in burst:                    # the misplaced burst in particular
        assert dg in cluster.by_id["peer0"].server.store, \
            "repair drill: burst key did not hand off to its primary"
    rstats = cluster.replication_stats()
    handoffs = sum(s["handoffs"] for s in rstats.values())
    assert handoffs >= len(burst), \
        "repair drill: hinted handoffs did not run"
    leaks = sum(s["leaks_repaired"] for s in rstats.values())
    client_up = sum(st.bytes_up for st in d.peer_stats().values())
    p2p = cluster.p2p_bytes()
    hints = sum(st.hints for st in d.peer_stats().values())
    assert p2p > 0 and hints == d.replications, \
        "repair drill: replication fan-out rode the client path"
    t_fab = mean_ttft(results)
    lines.append(csv_line(
        "cluster_repair_drill", t_fab * 1e6,
        f"killed=peer0@{kill_at};revived@{revive_at};"
        f"repair_rounds={repair_rounds};handoffs={handoffs};"
        f"leaks_repaired={leaks};client_up_bytes={client_up};"
        f"p2p_bytes={p2p};hot_hints={hints};"
        f"primary_readable=all;tokens_identical=True;"
        f"ttft_fabric={t_fab:.3f}s"))
    return lines


if __name__ == "__main__":
    main()
