"""Paper Table 2 / Figure 4: TTFT & TTLT, cache miss (Case 1) vs full hit
(Case 5), on the low-end and high-end edge settings.

Each prompt is inferred twice: cold (miss; uploads ranges) and again on a
second client (full hit). Reported latencies are the *sim* breakdown —
emulated Pi device + simulated Wi-Fi — averaged over the workload; the
reduced executable model guarantees hit/miss outputs are identical.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, make_world
from repro.data import MMLU_DOMAINS


def run_setting(setting: str, n_prompts: int = 24, max_new: int = None):
    w = make_world(setting)
    if max_new is None:
        # paper workload: ~57 output tokens low-end, ~2 high-end (Table 3)
        max_new = 57 if setting == "low" else 2
    c_miss = w.client("seeder")
    c_hit = w.client("reader")
    miss_t, hit_t = [], []
    mismatches = 0
    for i, p in enumerate(w.gen.stream(n_prompts,
                                       MMLU_DOMAINS[:n_prompts])):
        r1 = c_miss.infer(p.segments, max_new_tokens=max_new)
        assert r1.case == 1
        c_hit.sync_catalog()
        c_hit.catalog.last_sync_t = -1e18
        r2 = c_hit.infer(p.segments, max_new_tokens=max_new)
        assert r2.case == 5, r2.case
        if r1.output_tokens != r2.output_tokens:
            mismatches += 1
        miss_t.append((r1.sim.ttft, r1.sim.ttlt))
        hit_t.append((r2.sim.ttft, r2.sim.ttlt))
    miss = np.mean(miss_t, axis=0)
    hit = np.mean(hit_t, axis=0)
    return miss, hit, mismatches


def main():
    lines = []
    for setting, paper in (("low", (93.12, 50.07)), ("high", (-7.08, -7.10))):
        miss, hit, mism = run_setting(setting)
        ttft_red = 100 * (1 - hit[0] / miss[0])
        ttlt_red = 100 * (1 - hit[1] / miss[1])
        lines.append(csv_line(
            f"table2_{setting}_ttft", miss[0] * 1e6,
            f"miss={miss[0]:.3f}s;hit={hit[0]:.3f}s;"
            f"reduction={ttft_red:.2f}%;paper={paper[0]:.2f}%;"
            f"output_mismatches={mism}"))
        lines.append(csv_line(
            f"table2_{setting}_ttlt", miss[1] * 1e6,
            f"miss={miss[1]:.3f}s;hit={hit[1]:.3f}s;"
            f"reduction={ttlt_red:.2f}%;paper={paper[1]:.2f}%"))
    return lines


if __name__ == "__main__":
    main()
