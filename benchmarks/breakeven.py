"""Paper §5.3 break-even analysis, generalized (beyond-paper): for each
device class x architecture, the prompt length-independent ratio

    gain(n) = TTFT_hit(n) / TTFT_miss(n)
            ~ transfer(state_bytes(n)) / prefill(n)

determines whether distributed prompt caching pays. We sweep bandwidth and
device speed, and place every assigned architecture on the map (MLA's
compact latent cache vs dense GQA vs SSM constant state)."""
from __future__ import annotations


from benchmarks.common import csv_line
from repro.configs import get_config
from repro.configs.registry import ASSIGNED
from repro.core.netsim import SimNetwork
from repro.core.perfmodel import PI_5, PI_ZERO_2W, TPU_V5E
from repro.core.sizing import state_bytes


def breakeven_bandwidth(cfg, perf, n_tokens: int = 405) -> float:
    """Bandwidth (bit/s) where full-hit TTFT == miss TTFT."""
    t_prefill = perf.time_prefill(cfg, n_tokens)
    nbytes = state_bytes(cfg, n_tokens)
    if t_prefill <= 0:
        return float("inf")
    return nbytes * 8.0 / t_prefill


def main():
    lines = []
    # paper's own settings
    for name, cfg_name, perf in (("low", "gemma3-270m", PI_ZERO_2W),
                                 ("high", "gemma3-1b", PI_5)):
        cfg = get_config(cfg_name)
        bw = breakeven_bandwidth(cfg, perf)
        wifi = SimNetwork().bandwidth_bps
        wins = "hit-wins" if bw < wifi else "miss-wins"
        lines.append(csv_line(
            f"breakeven_{name}", bw,
            f"breakeven_bw={bw / 1e6:.2f}Mbps;wifi=21Mbps;{wins};"
            f"state_bytes={state_bytes(cfg, 405)};"
            f"prefill_405tok={perf.time_prefill(cfg, 405):.2f}s"))

    # every assigned architecture on a TPU v5e replica over 100 Gb/s DCN
    dcn = 100e9
    for arch in sorted(ASSIGNED):
        cfg = get_config(arch)
        bw = breakeven_bandwidth(cfg, TPU_V5E, n_tokens=32768)
        lines.append(csv_line(
            f"breakeven_tpu_{arch}", bw,
            f"breakeven_bw={bw / 1e9:.2f}Gbps;dcn=100Gbps;"
            f"{'hit-wins' if bw < dcn else 'miss-wins'};"
            f"state_MB_32k={state_bytes(cfg, 32768) / 1e6:.1f}"))
    return lines


if __name__ == "__main__":
    main()
