"""Paper §5.2.4: Bloom-filter false-positive impact, at the paper's exact
catalog configuration (1M capacity, 1% target)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, make_world
from repro.core.bloom import BloomFilter


def main():
    bf = BloomFilter(capacity=1_000_000, fp_rate=0.01)
    rng = np.random.default_rng(0)
    n_inserted = 1_000_000
    for _ in range(n_inserted):
        bf.add(rng.bytes(16))
    probes = 200_000
    fp = sum(rng.bytes(17) in bf for _ in range(probes)) / probes

    # expected Case-1 TTFT penalty = fp * (wasted GET round trip)
    w = make_world("low")
    wasted = w.net.transfer_time(256)              # miss response is tiny
    paper_penalty = 0.86 * 0.01                    # paper's own estimate
    lines = [csv_line(
        "bloom_fp_at_capacity", fp * 1e6,
        f"fp_rate={fp:.4f};target=0.01;size_MB={bf.size_bytes / 1e6:.2f};"
        f"k={bf.k};case1_ttft_penalty_ms={fp * wasted * 1e3:.3f};"
        f"paper_penalty_ms={paper_penalty * 1e3:.1f}")]
    return lines


if __name__ == "__main__":
    main()
