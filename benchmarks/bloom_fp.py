"""Paper §5.2.4: Bloom-filter false-positive impact, at the paper's exact
catalog configuration (1M capacity, 1% target) — plus the *stale-catalog*
false-positive rate under LRU eviction, measured directly from the
server's tombstone counter (exposed through the ``sync`` op) instead of
inferred from failed GETs."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, make_world
from repro.config import CacheConfig
from repro.core import CacheServer
from repro.core.bloom import BloomFilter


def stale_catalog_fp():
    """Evictions poison the catalogs: every tombstoned key is a
    guaranteed false positive for any client that synced it. The sync
    op now reports the tombstone count, so the stale-FP rate is
    tombstones/version — cross-checked here against realized GETs."""
    server = CacheServer(CacheConfig(max_store_bytes=512 * 1024))
    rng = np.random.default_rng(0)
    keys = [rng.bytes(32) for _ in range(400)]
    for k in keys:
        server.put(k, rng.bytes(4096))
    resp = server.handle("sync", {"since": 0})
    reported = resp["tombstones"] / max(resp["version"], 1)
    failed = sum(server.get(k) is None for k in keys) / len(keys)
    return reported, failed, resp["tombstones"]


def main():
    bf = BloomFilter(capacity=1_000_000, fp_rate=0.01)
    rng = np.random.default_rng(0)
    n_inserted = 1_000_000
    for _ in range(n_inserted):
        bf.add(rng.bytes(16))
    probes = 200_000
    fp = sum(rng.bytes(17) in bf for _ in range(probes)) / probes

    # expected Case-1 TTFT penalty = fp * (wasted GET round trip)
    w = make_world("low")
    wasted = w.net.transfer_time(256)              # miss response is tiny
    paper_penalty = 0.86 * 0.01                    # paper's own estimate
    lines = [csv_line(
        "bloom_fp_at_capacity", fp * 1e6,
        f"fp_rate={fp:.4f};target=0.01;size_MB={bf.size_bytes / 1e6:.2f};"
        f"k={bf.k};case1_ttft_penalty_ms={fp * wasted * 1e3:.3f};"
        f"paper_penalty_ms={paper_penalty * 1e3:.1f}")]

    reported, failed, n_tomb = stale_catalog_fp()
    lines.append(csv_line(
        "bloom_stale_catalog_fp", reported * 1e6,
        f"stale_fp_rate={reported:.4f};realized_failed_get={failed:.4f};"
        f"tombstones={n_tomb};ttft_penalty_per_stale_hit_ms="
        f"{wasted * 1e3:.3f}"))
    return lines


if __name__ == "__main__":
    main()
