"""Engine micro-benchmarks (real wall time on this host): prefill and
decode us/call for the reduced executable model, plus state blob
serialize/restore throughput — the operations on the paper's critical
path."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_line, make_world
from repro.core import state_io
from repro.core.keys import model_meta
from repro.serving.engine import InferenceEngine


def main():
    w = make_world("low")
    eng = InferenceEngine(w.model, w.params, max_len=256)
    toks = np.arange(3, 131, dtype=np.int32)[None]
    # warm up compile
    st = eng.start({"tokens": toks})
    eng.generate(st, 4)

    n = 10
    t0 = time.perf_counter()
    for _ in range(n):
        st = eng.start({"tokens": toks})
    t_pref = (time.perf_counter() - t0) / n
    st = eng.start({"tokens": toks})
    t0 = time.perf_counter()
    eng.generate(st, 32)
    t_dec = (time.perf_counter() - t0) / 32

    meta = model_meta(w.exec_cfg, "float32")
    t0 = time.perf_counter()
    for _ in range(n):
        blob = state_io.extract_state(st.cache, 128, meta,
                                      logits=st.last_logits)
    t_ser = (time.perf_counter() - t0) / n
    template = eng.new_cache()
    payload = state_io.parse_state(blob, meta)
    t0 = time.perf_counter()
    for _ in range(n):
        state_io.restore_state(payload, template)
    t_res = (time.perf_counter() - t0) / n

    return [
        csv_line("engine_prefill_128tok", t_pref * 1e6,
                 f"tok_per_s={128 / t_pref:.0f}"),
        csv_line("engine_decode_step", t_dec * 1e6,
                 f"tok_per_s={1 / t_dec:.1f}"),
        csv_line("state_serialize_128tok", t_ser * 1e6,
                 f"blob_bytes={len(blob)};MBps={len(blob) / t_ser / 1e6:.1f}"),
        csv_line("state_restore_128tok", t_res * 1e6,
                 f"MBps={len(blob) / t_res / 1e6:.1f}"),
    ]


if __name__ == "__main__":
    main()
