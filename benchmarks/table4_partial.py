"""Paper Table 4 / Figure 5: total decoding time under partial-matching
Cases 1-5 (astronomy, N=5 shots). For each case the server is seeded with
exactly one prefix range so the client resumes from it; T-decode =
P-decode + R-decode (paper's definition, Redis excluded) plus the Fig-5
view with Redis included."""
from __future__ import annotations

from benchmarks.common import csv_line, make_world


def run_setting(setting: str):
    w = make_world(setting)
    max_new = 57 if setting == "low" else 2
    # paper §5.2.2: this analysis uses a single astronomy prompt with N=5
    # examples in BOTH settings (405 tokens in the paper)
    from repro.data import MMLUGenerator, WordHashTokenizer
    gen5 = MMLUGenerator(WordHashTokenizer(w.exec_cfg.vocab), n_shot=5,
                         question_words=(24, 40), example_words=(24, 40))
    p = gen5.prompt("astronomy", 0)
    n = len(p.segments.token_ids)
    bounds = list(p.segments.boundaries)      # [instr, +ex1, +all, full]
    results = {}
    # Case 1: nothing cached
    c = w.client("case1")
    r = c.infer(p.segments, max_new_tokens=max_new, upload_on_miss=False)
    results[1] = (1, r)
    # Cases 2..5: seed exactly one range, fresh client each time
    for case, b in zip((2, 3, 4, 5), bounds):
        w.server.__init__(w.server.cfg)
        seeder = w.client("seed")
        seeder.infer(p.segments, max_new_tokens=1)     # uploads all ranges
        # strip all but the target range from a fresh reader's view
        reader = w.client(f"case{case}")
        keys = p.segments.keys(reader.meta)
        target = next(k for k in keys if k.n_tokens == b)
        reader.catalog.register(target.digest)
        r = reader.infer(p.segments, max_new_tokens=max_new,
                         upload_on_miss=False)
        results[case] = (b, r)
    return n, results


def main():
    lines = []
    paper_low = {1: 27203.96, 2: 26288.23, 3: 24590.09, 4: 13344.96,
                 5: 11220.95}
    paper_high = {1: 3361.88, 2: 3280.38, 3: 2918.08, 4: 643.35, 5: 62.9}
    for setting, paper in (("low", paper_low), ("high", paper_high)):
        n, results = run_setting(setting)
        for case, (matched, r) in sorted(results.items()):
            t_dec = (r.sim.p_decode + r.sim.r_decode) * 1e3      # ms
            with_redis = t_dec + r.sim.redis * 1e3
            lines.append(csv_line(
                f"table4_{setting}_case{case}", t_dec * 1e3,
                f"matched={r.matched_tokens}/{n}"
                f"({100 * r.matched_tokens / n:.1f}%);"
                f"t_decode={t_dec:.1f}ms;with_redis={with_redis:.1f}ms;"
                f"paper_t_decode={paper[case]:.1f}ms"))
    return lines


if __name__ == "__main__":
    main()
