"""Beyond-paper: stride-based range registration vs the paper's fixed 4
structural ranges (§3.2 generalization, SGLang/radix-adjacent).

Workload: prompts that diverge INSIDE a segment (shared instruction, then
example lists that share a prefix of examples but differ midway) — the
paper's 4-range scheme can only match at segment boundaries, the stride
scheme matches at the last shared stride boundary. Reports matched-token
gain vs upload-cost increase."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, make_world
from repro.config import CacheConfig
from repro.core import CacheServer, EdgeClient
from repro.core.transport import InProcTransport
from repro.serving.engine import InferenceEngine
from repro.core.segments import PromptSegments


def make_diverging_prompts(gen, domain: str, n_shared_examples: int = 3):
    """Two prompts sharing instruction + first k examples, then differing
    in later examples AND the question (divergence inside the 'examples'
    segment — invisible to boundary-only matching)."""
    base = gen.prompt(domain, 0)
    ids = list(base.segments.token_ids)
    instr = base.instruction_len
    exl = base.example_lens
    cut = instr + sum(exl[:n_shared_examples])
    # prompt B: same up to `cut`, then fresh tail of the same length
    rng = np.random.default_rng(99)
    tail = [int(x) for x in rng.integers(16, 4000, len(ids) - cut)]
    ids_b = ids[:cut] + tail
    seg_b = PromptSegments.mmlu_style(ids_b, instr, exl)
    return base.segments, seg_b, cut


def run(stride: int):
    w = make_world("low")
    from repro.data import MMLUGenerator, WordHashTokenizer
    gen5 = MMLUGenerator(WordHashTokenizer(w.exec_cfg.vocab), n_shot=5,
                         question_words=(24, 40), example_words=(24, 40))
    server = CacheServer(CacheConfig())
    ccfg = CacheConfig(range_stride=stride)

    def client(name):
        eng = InferenceEngine(w.model, w.params, max_len=1024)
        tr = InProcTransport(server, w.net, w.clock)
        return EdgeClient(name, eng, tr, ccfg, perf=w.perf, perf_cfg=w.cfg)

    matched, upload, n_tot = [], [], 0
    for domain in ("astronomy", "virology", "marketing"):
        a, b, cut = make_diverging_prompts(gen5, domain)
        writer, reader = client("w"), client("r")
        r1 = writer.infer(a, max_new_tokens=2)
        upload.append(r1.blob_bytes_up)
        reader.sync_catalog()
        r2 = reader.infer(b, max_new_tokens=2, upload_on_miss=False)
        matched.append((r2.matched_tokens, cut, len(b.token_ids)))
    return matched, float(np.mean(upload))


def main():
    lines = []
    base_match, base_up = run(stride=0)
    strided_match, strided_up = run(stride=16)
    bm = np.mean([m / c for m, c, _ in base_match])
    sm = np.mean([m / c for m, c, _ in strided_match])
    lines.append(csv_line(
        "range_stride16_vs_paper4", strided_up,
        f"matched_frac_of_shared(paper4)={bm:.2f};"
        f"matched_frac_of_shared(stride16)={sm:.2f};"
        f"upload_bytes(paper4)={base_up:.0f};"
        f"upload_bytes(stride16)={strided_up:.0f};"
        f"upload_cost_x={strided_up / max(base_up, 1):.1f}"))
    return lines


if __name__ == "__main__":
    main()
