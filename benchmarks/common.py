"""Shared benchmark world: reduced executable models emulating the paper's
full-size Gemma-3 settings through the device perf model + analytic blob
sizing (see core/sizing.py)."""
from __future__ import annotations

import json
import time
from dataclasses import dataclass

import jax

from repro.config import CacheConfig
from repro.configs import get_config
from repro.core import CacheServer, EdgeClient, SimClock, SimNetwork
from repro.core.perfmodel import PI_5, PI_ZERO_2W, TPU_V5E
from repro.core.transport import InProcTransport
from repro.data import MMLUGenerator, WordHashTokenizer
from repro.models import Model
from repro.serving.engine import InferenceEngine


@dataclass
class World:
    name: str
    cfg: object            # full-size config (perf emulation)
    exec_cfg: object       # reduced executable config
    model: object
    params: object
    server: CacheServer
    clock: SimClock
    net: SimNetwork
    gen: MMLUGenerator
    perf: object
    n_shot: int

    def client(self, name: str, **kw) -> EdgeClient:
        eng = InferenceEngine(self.model, self.params, max_len=1024)
        tr = InProcTransport(self.server, self.net, self.clock)
        return EdgeClient(name, eng, tr, CacheConfig(), perf=self.perf,
                          perf_cfg=self.cfg, **kw)


_CACHE = {}


def make_world(setting: str = "low") -> World:
    """'low' = Pi Zero 2W + Gemma-3 270M (N=1 shot);
    'high' = Pi 5 + Gemma-3 1B (N=5 shot);
    'tpu'  = v5e serving replica (beyond-paper)."""
    if setting in _CACHE:
        w = _CACHE[setting]
        w.server.__init__(CacheConfig())     # fresh server per bench
        w.clock.t = 0.0
        return w
    full = {"low": "gemma3-270m", "high": "gemma3-1b",
            "tpu": "gemma3-1b"}[setting]
    perf = {"low": PI_ZERO_2W, "high": PI_5, "tpu": TPU_V5E}[setting]
    n_shot = 1 if setting == "low" else 5
    cfg = get_config(full)
    exec_cfg = cfg.replace(name=cfg.name + "-exec", n_layers=2,
                           d_model=128, n_heads=4, n_kv_heads=1,
                           head_dim=32, d_ff=256, vocab=4096)
    model = Model(exec_cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = WordHashTokenizer(exec_cfg.vocab)
    gen = MMLUGenerator(tok, n_shot=n_shot,
                        question_words=(24, 40), example_words=(24, 40))
    w = World(setting, cfg, exec_cfg, model, params, CacheServer(
        CacheConfig()), SimClock(), SimNetwork(), gen, perf, n_shot)
    _CACHE[setting] = w
    return w


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0)


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line


def merge_rollups(into: dict, rollup: dict) -> dict:
    """Accumulate ``Tracer.rollup()`` dicts across benchmark stages
    (each stage may own a short-lived tracer)."""
    for name, agg in rollup.items():
        tot = into.setdefault(name, {"count": 0, "total_s": 0.0})
        tot["count"] += agg["count"]
        tot["total_s"] += agg["total_s"]
    return into


def write_bench(path: str, payload: dict, spans: dict = None) -> None:
    """Write a ``BENCH_*.json`` report with the run's observability
    state attached under ``"obs"``: the process-wide Prometheus metrics
    snapshot plus *spans*, a per-span-name rollup ({name: {count,
    total_s}}, see ``Tracer.rollup``) when the benchmark ran with
    tracing. Keeps every bench artifact self-describing — a regression
    report carries the phase breakdown that explains it."""
    from repro.obs import REGISTRY

    obs: dict = {"metrics": REGISTRY.snapshot()}
    if spans:
        obs["spans"] = spans
    payload = dict(payload)
    payload["obs"] = obs
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
