"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Usage:
    PYTHONPATH=src python -m benchmarks.run [--only substr]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "benchmarks.table2_ttft_ttlt",     # paper Table 2 / Fig 4
    "benchmarks.table3_breakdown",     # paper Table 3
    "benchmarks.table4_partial",       # paper Table 4 / Fig 5
    "benchmarks.bloom_fp",             # paper §5.2.4
    "benchmarks.catalog_ablation",     # paper §5.2.3
    "benchmarks.breakeven",            # paper §5.3 + beyond-paper
    "benchmarks.quantized_blobs",      # beyond-paper: int8 KV blobs
    "benchmarks.range_stride",         # beyond-paper: dense range regs
    "benchmarks.workload_sim",         # full 6434-prompt workload (§5.1)
    "benchmarks.blob_pipeline",        # v3 chunk pipeline: overlap + 1-pass
    "benchmarks.cluster_sweep",        # multi-peer fabric vs single box
    "benchmarks.chaos_drill",          # seeded fault schedule, real fleet
    "benchmarks.gossip_convergence",   # epidemic fanout vs full mesh, N=16
    "benchmarks.engine_micro",         # substrate microbenchmarks
    "benchmarks.serving_throughput",   # continuous batching + sessions
    "benchmarks.gateway_load",         # HTTP front door: 3 replay mixes
    "benchmarks.obs_smoke",            # tracing overhead + telemetry
    "benchmarks.roofline_table",       # §Roofline (from dry-run records)
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
            print(f"# {mod_name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{mod_name},0,FAILED")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
