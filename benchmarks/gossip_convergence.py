"""Epidemic gossip fanout vs the full mesh: convergence at N=16.

Each of N peers starts holding a few unique keys. One *round* lets
every peer pull ``csync`` deltas from its partners — all N-1 of them in
the full mesh, or ``k`` random neighbors in the epidemic variant. We
measure rounds and total exchanged entries until every peer can
advertise every key (full knowledge), which is what bounds how stale a
client's per-peer catalogs can be.

The point: the full mesh converges in one round but costs O(N²)
exchanges per round — at N=16 that is 240 pulls per round, every
round, forever. Epidemic fanout k=2 pays O(N·k)=32 pulls per round and
still converges in O(log N) rounds, so the *steady-state* sync traffic
(the rounds after convergence, when nothing is new) drops ~8x.

    PYTHONPATH=src python -m benchmarks.gossip_convergence
"""
from __future__ import annotations

import random

from benchmarks.common import csv_line, timed
from repro.config import CacheConfig
from repro.core import CacheCluster
from repro.core.cluster.peer import gossip_round

N_PEERS = 16
KEYS_PER_PEER = 4
MAX_ROUNDS = 64


def build_cluster() -> tuple:
    cluster = CacheCluster([(21e6, 0.003)] * N_PEERS,
                           CacheConfig(bloom_capacity=10_000))
    digests = []
    for i, p in enumerate(cluster.peers):
        for j in range(KEYS_PER_PEER):
            d = bytes([i, j]) * 16
            p.server.put(d, b"x")
            digests.append(d)
    return cluster, digests


def converged(peers, digests) -> bool:
    return all(p.knows(d) for p in peers for d in digests)


def run(fanout, seed: int = 0):
    cluster, digests = build_cluster()
    peers = cluster.peers
    rng = random.Random(seed)
    rounds, pulls = 0, 0
    while rounds < MAX_ROUNDS and not converged(peers, digests):
        gossip_round(peers, fanout=fanout, rng=rng)
        rounds += 1
        per_round = (len(peers) * (len(peers) - 1) if fanout is None
                     else len(peers) * min(fanout, len(peers) - 1))
        pulls += per_round
    entries = sum(p.gossip_stats["keys_in"] for p in peers)
    wire = sum(p.gossip_stats["bytes"] for p in peers)
    return rounds, pulls, entries, wire, converged(peers, digests)


def main():
    lines = []
    for fanout in (None, 1, 2, 4):
        label = "mesh" if fanout is None else f"k{fanout}"
        (rounds, pulls, entries, wire, ok), dt = timed(run, fanout)
        assert ok or fanout == 1, \
            f"gossip fanout={fanout} failed to converge in {MAX_ROUNDS}"
        # steady-state pulls/round once converged is the recurring cost
        steady = (N_PEERS * (N_PEERS - 1) if fanout is None
                  else N_PEERS * (fanout or 0))
        lines.append(csv_line(
            f"gossip_convergence_{label}", dt / max(rounds, 1) * 1e6,
            f"n={N_PEERS};rounds={rounds};pulls={pulls};"
            f"entries={entries};wire_bytes={wire};"
            f"steady_pulls_per_round={steady};converged={ok}"))
    return lines


if __name__ == "__main__":
    main()
