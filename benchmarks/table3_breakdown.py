"""Paper Table 3: latency breakdown (Token / Bloom / P-decode / Redis /
R-decode / Sample) under Case 1 and Case 5, low-end and high-end."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, make_world
from repro.core.metrics import COMPONENTS
from repro.data import MMLU_DOMAINS

PAPER_MS = {   # msec from the paper's Table 3
    ("low", 1): dict(token=3.46, bloom=0.30, p_decode=12580.85, redis=2.42,
                     r_decode=11061.04, sample=95.69),
    ("low", 5): dict(token=3.46, bloom=0.19, p_decode=0.0, redis=861.92,
                     r_decode=10904.67, sample=84.82),
    ("high", 1): dict(token=1.61, bloom=0.0, p_decode=2688.17, redis=7.84,
                      r_decode=72.59, sample=1.45),
    ("high", 5): dict(token=1.56, bloom=0.0, p_decode=0.0, redis=2887.04,
                      r_decode=78.12, sample=1.67),
}


def run_setting(setting: str, n_prompts: int = 16):
    w = make_world(setting)
    # decode lengths per the paper: low-end ~57 output tokens, high-end ~2
    max_new = 57 if setting == "low" else 2
    c1, c2 = w.client("a"), w.client("b")
    rows = {1: [], 5: []}
    for p in w.gen.stream(n_prompts, MMLU_DOMAINS[:n_prompts]):
        r1 = c1.infer(p.segments, max_new_tokens=max_new)
        c2.sync_catalog()
        c2.catalog.last_sync_t = -1e18
        r2 = c2.infer(p.segments, max_new_tokens=max_new)
        rows[1].append(r1.sim.as_dict())
        rows[5].append(r2.sim.as_dict())
    return {case: {k: float(np.mean([r[k] for r in rs])) for k in rs[0]}
            for case, rs in rows.items()}


def main():
    lines = []
    for setting in ("low", "high"):
        avg = run_setting(setting)
        for case in (1, 5):
            parts = ";".join(f"{c}={avg[case][c] * 1e3:.2f}ms"
                             for c in COMPONENTS)
            paper = PAPER_MS[(setting, case)]
            ref = ";".join(f"paper_{k}={v:.2f}ms" for k, v in paper.items())
            lines.append(csv_line(
                f"table3_{setting}_case{case}",
                avg[case]["ttlt"] * 1e6, parts + ";" + ref))
    return lines


if __name__ == "__main__":
    main()
