"""Full-workload simulation: the paper's 6,434-prompt / 57-domain MMLU
evaluation, end to end.

The catalog/server/partial-matching logic is the REAL implementation
(Bloom filters, key hashing, range registration, async sync); only model
execution is replaced by the calibrated device perf model and transfers
by the Wi-Fi netsim — so the *hit-case mix* (how often Cases 1-5 actually
occur across the workload, which the per-prompt benchmarks cannot show)
is faithful. Validates the paper's averaged headline numbers:
TTFT -93.12 %, TTLT -50.07 % over the whole workload.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line
from repro.config import CacheConfig
from repro.configs import get_config
from repro.core import CacheServer, Catalog, SimNetwork
from repro.core.keys import model_meta
from repro.core.perfmodel import PI_5, PI_ZERO_2W
from repro.core.sizing import state_bytes
from repro.data import MMLUGenerator, WordHashTokenizer, MMLU_DOMAINS


class SimClient:
    """Steps 1-4 with real catalog logic, analytic compute/transfer."""

    def __init__(self, cfg, perf, net, server, ccfg, use_cache=True):
        self.cfg, self.perf, self.net = cfg, perf, net
        self.server, self.ccfg = server, ccfg
        self.catalog = Catalog(ccfg)
        self.meta = model_meta(cfg, "bfloat16")
        self.use_cache = use_cache
        self.version = 0

    def sync(self):
        keys, self.version = self.server.sync(self.version)
        for k in keys:
            self.catalog.bloom.add(k)

    def infer(self, prompt, n_out: int):
        cfg, perf, net = self.cfg, self.perf, self.net
        n = len(prompt.token_ids)
        keys = prompt.keys(self.meta, self.ccfg.max_ranges)
        ttft = perf.time_tokenize(n) + perf.time_bloom(len(keys))
        matched, case, fp = 0, 1, False
        if self.use_cache:
            for k in keys:
                if k.n_tokens < self.ccfg.min_match_tokens or \
                        k.digest not in self.catalog.bloom:
                    continue
                blob = self.server.get(k.digest)
                if blob is None:            # bloom false positive
                    ttft += net.transfer_time(256)
                    fp = True
                    continue
                full = k.n_tokens == n
                nb = state_bytes(cfg, k.n_tokens, with_logits=full)
                ttft += net.transfer_time(nb)
                matched = k.n_tokens
                break
        ttft += perf.time_prefill(cfg, n - matched)
        if matched == 0 and self.use_cache:
            for k in keys:                   # register ranges (async up)
                self.server.put(k.digest, b"1")
                self.catalog.register(k.digest)
        bounds = list(prompt.boundaries)
        if matched == n:
            case = 5
        elif matched in bounds:
            case = min(2 + bounds.index(matched), 4)
        ttlt = ttft + perf.time_decode(cfg, n_out) + perf.time_sample(n_out)
        return case, ttft, ttlt, fp


def run(setting: str, n_prompts: int = 6434, n_clients: int = 2):
    cfg = get_config("gemma3-270m" if setting == "low" else "gemma3-1b")
    perf = PI_ZERO_2W if setting == "low" else PI_5
    n_shot = 1 if setting == "low" else 5
    n_out = 57 if setting == "low" else 2
    tok = WordHashTokenizer(cfg.vocab)
    gen = MMLUGenerator(tok, n_shot=n_shot, question_words=(24, 48),
                        example_words=(24, 48))
    net = SimNetwork()
    ccfg = CacheConfig()
    server = CacheServer(ccfg)
    clients = [SimClient(cfg, perf, net, server, ccfg)
               for _ in range(n_clients)]
    baseline = SimClient(cfg, perf, net, server, ccfg, use_cache=False)

    rng = np.random.default_rng(0)
    cases = np.zeros(6, np.int64)
    ttfts, ttlts, base_ttfts, base_ttlts = [], [], [], []
    fps = 0
    for i, p in enumerate(gen.stream(n_prompts, MMLU_DOMAINS)):
        c = clients[int(rng.integers(n_clients))]
        c.sync()
        case, ttft, ttlt, fp = c.infer(p.segments, n_out)
        _, bttft, bttlt, _ = baseline.infer(p.segments, n_out)
        cases[case] += 1
        fps += fp
        ttfts.append(ttft)
        ttlts.append(ttlt)
        base_ttfts.append(bttft)
        base_ttlts.append(bttlt)
    return cases, np.asarray(ttfts), np.asarray(ttlts), \
        np.asarray(base_ttfts), np.asarray(base_ttlts), fps


def main():
    lines = []
    for setting, paper in (("low", (93.12, 50.07)), ("high", (-7.08, -7.10))):
        cases, ttft, ttlt, b_ttft, b_ttlt, fps = run(setting)
        red_f = 100 * (1 - ttft.mean() / b_ttft.mean())
        red_l = 100 * (1 - ttlt.mean() / b_ttlt.mean())
        mix = ";".join(f"case{i}={cases[i]}" for i in range(1, 6)
                       if cases[i])
        lines.append(csv_line(
            f"workload6434_{setting}", ttft.mean() * 1e6,
            f"avg_ttft={ttft.mean():.2f}s(no-cache {b_ttft.mean():.2f}s);"
            f"avg_ttlt={ttlt.mean():.2f}s(no-cache {b_ttlt.mean():.2f}s);"
            f"ttft_reduction={red_f:.2f}%(paper {paper[0]}%);"
            f"ttlt_reduction={red_l:.2f}%(paper {paper[1]}%);"
            f"{mix};bloom_fps={fps};"
            f"p50={np.median(ttft):.2f}s;p99={np.quantile(ttft, .99):.2f}s"))
    return lines


if __name__ == "__main__":
    main()
