"""Chaos drill: continuous churn over a real TCP fleet under a seeded
fault schedule.

A 3-daemon ``PeerSupervisor`` fleet serves an MMLU-style prompt stream
while a :class:`~repro.chaos.FaultDriver` replays a deterministic
:class:`~repro.chaos.FaultSchedule` against it — peer kills, asymmetric
partitions, chunk corruption, stalled streams, silent bandwidth
collapse, delayed acks — each fault paired with a heal a few steps
later. The graceful-degradation stack (circuit breakers, hedged
fetches, deadlines, the cancel frame, supervised restarts under the
storm guard) is what keeps the drill inside its envelope.

Hard assertions (the drill FAILS, not just reports):

* token identity — every churn response matches the cache-off anchor
* zero hangs — every request bounded, whole drill bounded
* >= 6 faults applied, spanning kill / partition / corrupt / stall
* replay determinism — regenerating the schedule from the same seed
  yields the same event order (and a JSON round-trip preserves it)
* bounded repair — the fleet is fully healthy again within a fixed
  number of supervision rounds after the schedule drains
* degradation machinery visibly engaged — breaker-open flight dump,
  hedged fetch, server-acked stream cancel, deadline-stamped ledger
  records

Emits ``BENCH_chaos_drill.json``. Usage::

    PYTHONPATH=src python -m benchmarks.chaos_drill [--quick]
"""
from __future__ import annotations

import sys
import threading
import time

from benchmarks.common import csv_line, make_world, write_bench
from repro.chaos import FaultDriver, FaultSchedule
from repro.config import CacheConfig
from repro.core import CacheServer, SimClock, SimNetwork
from repro.core.client import EdgeClient
from repro.core.net.supervisor import PeerSupervisor
from repro.core.transport import (InProcTransport, StreamCancelled,
                                  TransportError)
from repro.obs import REGISTRY
from repro.obs.flight import BREAKER_OPEN, FLIGHT
from repro.obs.ledger import LEDGER
from repro.serving.engine import InferenceEngine

SEED = 20260809
N_PEERS = 3
MAX_NEW = 4
REQUEST_WALL_BOUND_S = 60.0          # any single request over this = hang
DRILL_WALL_BOUND_S = 420.0           # whole churn loop, hard ceiling
MAX_REPAIR_ROUNDS = 8                # supervision sweeps to full health
DEADLINE_S = 30.0                    # generous e2e budget per request
FAULT_KINDS = ("kill", "partition", "corrupt", "stall", "bandwidth",
               "delay_ack")


def _counter(name: str) -> float:
    fam = REGISTRY.get(name)
    if fam is None:
        return 0.0
    snap = fam.snapshot()
    if isinstance(snap, dict):
        return float(sum(snap.values()))
    return float(snap)


def _fleet_cancels(sup: PeerSupervisor) -> int:
    total = 0
    for pid in sup.procs:
        try:
            st = sup.request(pid, "health", {})
        except TransportError:
            continue
        total += int(st.get("transport", {}).get("cancels", 0))
    return total


def _peer_keys(sup: PeerSupervisor, pid: str):
    try:
        return list(sup.request(pid, "sync", {"since": 0})["keys"])
    except TransportError:
        return []


def run_drill(quick: bool) -> dict:
    n_steps = 12 if quick else 24
    w = make_world("low")
    engine = InferenceEngine(w.model, w.params, max_len=1024)
    domains = ("anatomy", "virology", "astronomy")
    pool = [w.gen.prompt(d, q).segments for d in domains
            for q in range(2)]
    churn = [pool[i % len(pool)] for i in range(n_steps)]

    # -- cache-off anchor: every prompt prefills locally ---------------
    off = EdgeClient("chaos-off", engine,
                     InProcTransport(CacheServer(CacheConfig()),
                                     SimNetwork(), SimClock()),
                     CacheConfig())
    anchor = [off.infer(p, max_new_tokens=MAX_NEW,
                        upload_on_miss=False).output_tokens
              for p in pool]
    want = [anchor[i % len(pool)] for i in range(n_steps)]

    report: dict = {"seed": SEED, "n_steps": n_steps,
                    "n_peers": N_PEERS, "quick": quick}
    with PeerSupervisor.fleet(N_PEERS, request_timeout_s=2.0,
                              restart_backoff_s=0.2,
                              restart_backoff_max_s=2.0,
                              restart_stable_s=5.0) as sup:
        sup.wire_gossip()
        d = sup.directory(suspect_cooldown_s=1.0, breaker_threshold=2,
                          breaker_backoff_s=0.3, hot_threshold=1,
                          hedge_floor_s=0.05)
        client = EdgeClient("chaos-drill", engine, d, CacheConfig())

        # -- seed the fleet (and a clean-TTFT reference pass) ----------
        for p in pool:
            d.last_sync_t = -1e18
            client.sync_catalog()
            client.infer(p, max_new_tokens=MAX_NEW)
        clean_walls = []
        for p in pool:
            d.last_sync_t = -1e18
            client.sync_catalog()
            t0 = time.perf_counter()
            r = client.infer(p, max_new_tokens=MAX_NEW)
            clean_walls.append(time.perf_counter() - t0)
            assert r.output_tokens == anchor[pool.index(p)]
        clean_mean = sum(clean_walls) / len(clean_walls)

        # -- seeded fault schedule: deterministic + replayable ---------
        peers = list(sup.procs)
        sched = FaultSchedule.generate(SEED, peers, n_steps=n_steps,
                                       n_faults=12, heal_after=3)
        replay = FaultSchedule.generate(SEED, peers, n_steps=n_steps,
                                        n_faults=12, heal_after=3)
        assert sched.event_order() == replay.event_order(), \
            "same seed must reproduce the same fault event order"
        assert (FaultSchedule.from_json(sched.to_json()).event_order()
                == sched.event_order())
        driver = FaultDriver(sup, sched)

        # -- churn loop under injected faults --------------------------
        walls, repairs, mismatches = [], 0, []
        t_drill = time.perf_counter()
        for step, p in enumerate(churn):
            driver.advance(step)
            repairs += len(sup.check_and_restart())
            d.last_sync_t = -1e18
            client.sync_catalog()
            t0 = time.perf_counter()
            r = client.infer(p, max_new_tokens=MAX_NEW,
                             deadline_s=DEADLINE_S)
            wall = time.perf_counter() - t0
            walls.append(wall)
            assert wall < REQUEST_WALL_BOUND_S, \
                f"request at step {step} took {wall:.1f}s — a hang"
            if r.output_tokens != want[step]:
                mismatches.append(step)
        drill_wall = time.perf_counter() - t_drill
        driver.finish()
        driver.heal_all()

        assert not mismatches, \
            f"token mismatch vs cache-off at steps {mismatches}"
        assert drill_wall < DRILL_WALL_BOUND_S, \
            f"drill took {drill_wall:.0f}s (bound {DRILL_WALL_BOUND_S})"

        # -- fault coverage --------------------------------------------
        applied = [e for e in driver.applied if e.kind in FAULT_KINDS]
        kinds = {e.kind for e in applied}
        assert len(applied) >= 6, \
            f"only {len(applied)} faults applied (skipped: " \
            f"{[e.fingerprint() for e in driver.skipped]})"
        for must in ("kill", "partition", "corrupt", "stall"):
            assert must in kinds, f"no {must!r} fault was applied"

        # -- bounded repair: fleet fully healthy again -----------------
        rounds = 0
        while rounds < MAX_REPAIR_ROUNDS:
            if all(sup.health().values()):
                break
            sup.check_and_restart()
            rounds += 1
            time.sleep(0.4)
        assert all(sup.health().values()), \
            f"fleet not healthy after {MAX_REPAIR_ROUNDS} repair rounds"

        # -- degradation probes: breaker / hedge / cancel, on demand ---
        # breaker: kill a peer and let two consecutive failures trip it
        victim = peers[0]
        sup.kill(victim, hard=True)
        for _ in range(int(d.links[victim].breaker.fail_threshold)):
            try:
                d.request(victim, "ping", {})
            except TransportError:
                pass   # expected: dead peer; breaker counts it
        assert d.breaker_states()[victim]["state"] == "open"
        assert any(dmp["reason"] == BREAKER_OPEN
                   for dmp in FLIGHT.dumps()), \
            "breaker open produced no flight dump"
        sup.restart(victim)

        # cancel: stall a stream server-side, abort it via the cancel
        # frame before the first chunk leaves
        holder = next((pid for pid in peers if _peer_keys(sup, pid)),
                      None)
        assert holder is not None, "no peer holds any key after churn"
        key = _peer_keys(sup, holder)[0]
        sup.inject_faults(holder, chaos={"stall_chunk_s": 0.4})
        ev = threading.Event()
        ev.set()
        try:
            d.request_stream(holder, "get_chunks", {"key": key},
                             lambda b, dt, nb: None, cancel=ev)
        except StreamCancelled:
            pass
        sup.inject_faults(holder, reset=True)
        cancels = _fleet_cancels(sup)
        assert cancels >= 1, "cancel frame was never acked by a peer"

        # hedge: replicate every stored key onto every peer, slow every
        # ack, and let the client's patience run out on the primary —
        # the plan's #2 candidate gets the duplicate GET
        seen: dict = {}
        for pid in peers:
            for k in _peer_keys(sup, pid):
                seen.setdefault(bytes(k), []).append(pid)
        for k, holders in seen.items():
            blob = d.request(holders[0], "get", {"key": k})[0]["blob"]
            for pid in peers:
                if pid not in holders:
                    d.request(pid, "put", {"key": k, "blob": blob})
        for pid in peers:
            sup.inject_faults(pid, chaos={"delay_ack_s": 0.4})
        hedges_before = _counter("client_hedge_total")
        d.last_sync_t = -1e18
        client.sync_catalog()
        r_hot = client.infer(churn[0], max_new_tokens=MAX_NEW)
        assert r_hot.output_tokens == want[0]
        for pid in peers:
            sup.inject_faults(pid, reset=True)
        hedges = _counter("client_hedge_total")
        report["hedges_fired"] = hedges - hedges_before
        assert hedges > hedges_before, "hedged fetch never fired"

        # deadline visibility: every churn request carried its budget
        # into the decision ledger
        stamped = sum(1 for rec in LEDGER.records(512)
                      if rec.get("deadline_s"))
        assert stamped >= 1, "no ledger record carries a deadline"

        # -- report ----------------------------------------------------
        churn_mean = sum(walls) / len(walls)
        report.update({
            "event_order": sched.event_order(),
            "applied_order": driver.applied_order(),
            "n_faults_applied": len(applied),
            "fault_kinds_applied": sorted(kinds),
            "n_skipped": len(driver.skipped),
            "supervised_restarts": repairs,
            "repair_rounds_to_healthy": rounds,
            "drill_wall_s": drill_wall,
            "clean_mean_wall_s": clean_mean,
            "churn_mean_wall_s": churn_mean,
            "churn_max_wall_s": max(walls),
            "ttft_degradation_x": churn_mean / max(clean_mean, 1e-9),
            "breaker_states": d.breaker_states(),
            "restart_states": sup.restart_states(),
            "cancels_acked": cancels,
            "ledger_deadline_records": stamped,
            "flight_dump_reasons": [dmp["reason"]
                                    for dmp in FLIGHT.dumps()],
        })
        # degradation envelope: churn may be slower (it pays timeouts
        # and local prefills) but must stay within a bounded multiple
        # of the clean pass plus absolute slack for backoffs
        assert churn_mean <= clean_mean * 100.0 + 10.0, \
            f"TTFT degraded {report['ttft_degradation_x']:.0f}x " \
            f"under churn — outside the envelope"
    return report


def main():
    quick = "--quick" in sys.argv
    report = run_drill(quick)
    csv_line("chaos_drill_faults_applied",
             report["n_faults_applied"], "count")
    csv_line("chaos_drill_churn_mean",
             report["churn_mean_wall_s"] * 1e6, "us_wall")
    csv_line("chaos_drill_ttft_degradation",
             report["ttft_degradation_x"], "x_vs_clean")
    csv_line("chaos_drill_repair_rounds",
             report["repair_rounds_to_healthy"], "rounds")
    write_bench("BENCH_chaos_drill.json", report)
    print(f"# chaos_drill: {report['n_faults_applied']} faults "
          f"({', '.join(report['fault_kinds_applied'])}), "
          f"{report['supervised_restarts']} supervised restarts, "
          f"degradation {report['ttft_degradation_x']:.1f}x",
          file=sys.stderr)


if __name__ == "__main__":
    main()
