"""Gateway load replay: three production mixes over a real TCP fabric.

End-to-end through every layer this repo has: seeded workload
generators (``repro.workloads``) -> HTTP/SSE against the OpenAI-style
gateway -> continuous-batching scheduler -> blocking prompt-cache
resolve/upload against a ``Fabric.tcp`` fleet of real
``PeerSupervisor`` daemon processes.

Per mix it reports client-observed TTFT/TTLT p50/p95, shed rate,
cache traffic, and a nominal cost-per-1K-requests (device-hours +
egress). Two acceptance checks run inline:

* **token identity** — every gateway completion must match a direct
  in-process ``Scheduler`` run of the same prompt (greedy, same
  model/params/max_len), cache hits included;
* **bounded shedding** — a burst against a 1-slot gateway must shed
  with 429/503 + ``Retry-After`` instead of queueing unboundedly.

Emits ``BENCH_gateway_load.json``. Usage::

    PYTHONPATH=src python -m benchmarks.gateway_load [--quick] [--mix m]
"""
from __future__ import annotations

import argparse
import http.client
import json
import threading
import time

import numpy as np

from benchmarks.common import csv_line, merge_rollups, write_bench
from repro.config import CacheConfig
from repro.configs import get_config
from repro.core import Fabric
from repro.data import WordHashTokenizer
from repro.gateway import Gateway, TenantQuota, protocol
from repro.models import Model
from repro.serving import BatchedEngine, Request, Scheduler
from repro.workloads import MIXES

MAX_LEN = 384
MAX_NEW = 8
# nominal fleet economics: edge device $/hr per box, LAN egress $/GB
DEVICE_USD_PER_HR = 0.12
EGRESS_USD_PER_GB = 0.02


# ---------------------------------------------------------------------------
# HTTP replay client (stdlib only; SSE readline gives client-side TTFT)
# ---------------------------------------------------------------------------

def _stream_one(host: str, port: int, wl, out: dict) -> None:
    t0 = time.perf_counter()
    try:
        conn = http.client.HTTPConnection(host, port, timeout=120)
        conn.request("POST", "/v1/chat/completions",
                     json.dumps(wl.body(stream=True)),
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        out["status"] = resp.status
        if resp.status != 200:
            out["retry_after"] = resp.getheader("Retry-After")
            resp.read()
            return
        tokens = []
        while True:
            line = resp.readline()
            if not line:
                break
            if not line.startswith(b"data: "):
                continue
            payload = line[6:].strip()
            if payload == b"[DONE]":
                break
            chunk = json.loads(payload)
            out["id"] = chunk.get("id", out.get("id"))
            choice = chunk["choices"][0]
            if "token_id" in choice:
                if not tokens:
                    out["ttft_s"] = time.perf_counter() - t0
                tokens.append(choice["token_id"])
        out["ttlt_s"] = time.perf_counter() - t0
        out["tokens"] = tokens
    except Exception as e:            # noqa: BLE001 — record, don't hang
        out["error"] = repr(e)
    finally:
        try:
            conn.close()
        except Exception:
            pass


def replay(gw, requests, time_scale: float = 1.0):
    """Fire each request at its (scaled) arrival offset, concurrently."""
    results = [dict() for _ in requests]
    t0 = time.perf_counter()

    def worker(i, wl):
        delay = wl.arrival_s * time_scale - (time.perf_counter() - t0)
        if delay > 0:
            time.sleep(delay)
        _stream_one(gw.server.host, gw.port, wl, results[i])

    threads = [threading.Thread(target=worker, args=(i, wl), daemon=True)
               for i, wl in enumerate(requests)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(180)
    wall = time.perf_counter() - t0
    return results, wall


def _get_json(gw, path: str):
    conn = http.client.HTTPConnection(gw.server.host, gw.port,
                                      timeout=30)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return resp.status, json.loads(resp.read())
    finally:
        conn.close()


# ---------------------------------------------------------------------------
# acceptance: every served request resolves to a populated decision
# ---------------------------------------------------------------------------

def resolve_decisions(gw, results, name: str) -> dict:
    """``GET /v1/decisions/<completion-id>`` for every 200 response:
    each must resolve to a record with its candidate set and a
    committed outcome (realized timings, regret)."""
    checked, regret, savings = 0, 0.0, 0.0
    by_result: dict = {}
    for r in results:
        if r.get("status") != 200:
            continue
        rid = r.get("id")
        assert rid, f"{name}: streamed response carried no id: {r}"
        status, rec = _get_json(gw, f"/v1/decisions/{rid}")
        assert status == 200, \
            f"{name}: {rid} has no decision record ({status})"
        assert "candidates" in rec and "attempts" in rec, rec
        oc = rec.get("outcome")
        assert oc, f"{name}: decision {rec.get('id')} never committed"
        assert oc["result"] in ("hit", "partial", "local"), oc
        assert oc["realized_total_s"] >= 0.0, oc
        assert oc["regret_s"] >= 0.0, oc
        assert "fallthroughs" in oc and "ttft_s" in oc, oc
        by_result[oc["result"]] = by_result.get(oc["result"], 0) + 1
        regret += oc["regret_s"]
        if oc.get("savings_vs_local_s") is not None:
            savings += oc["savings_vs_local_s"]
        checked += 1
    assert checked == sum(1 for r in results if r.get("status") == 200)
    return {"resolved": checked, "by_result": by_result,
            "regret_s": regret, "ttft_savings_vs_local_s": savings}


# ---------------------------------------------------------------------------
# acceptance: token identity vs a direct in-process scheduler run
# ---------------------------------------------------------------------------

def direct_tokens(model, params, tok, requests):
    """Greedy reference completions, no gateway, no cache."""
    eng = BatchedEngine(model, params, max_len=MAX_LEN, batch_size=2)
    sched = Scheduler(eng)
    reqs = []
    for wl in requests:
        segs = protocol.tokenize_messages(tok, wl.messages)
        reqs.append(Request(tokens=np.asarray(segs.token_ids, np.int32),
                            max_new_tokens=wl.max_new_tokens))
    sched.run(reqs)
    return [r.stats.output_tokens for r in reqs]


# ---------------------------------------------------------------------------
# acceptance: bounded shedding under slot exhaustion
# ---------------------------------------------------------------------------

def shed_drill(model, params, burst: int = 6) -> dict:
    """Burst a 1-slot gateway (queue_depth=1): extras must shed with
    429/503 + Retry-After, never queue unboundedly."""
    gw = Gateway(model, params, fabric=None, batch_size=1,
                 max_len=MAX_LEN, max_inflight=1, queue_depth=1,
                 default_quota=TenantQuota(max_concurrent=burst),
                 model_name="shed-drill").start()
    try:
        wls = MIXES["support"](burst, seed=7, rate_per_s=0.0,
                               max_new_tokens=48)
        results, wall = replay(gw, wls)
    finally:
        gw.stop()
    statuses = [r.get("status") for r in results]
    shed = [r for r in results if r.get("status") in (429, 503)]
    ok = [r for r in results if r.get("status") == 200]
    assert not any("error" in r for r in results), \
        f"shed drill had transport errors: {results}"
    assert all(s in (200, 429, 503) for s in statuses), \
        f"unexpected statuses under overload: {statuses}"
    assert shed, "slot exhaustion did not shed any requests"
    assert all(r.get("retry_after") for r in shed), \
        "shed responses missing Retry-After"
    assert ok, "overloaded gateway served nothing at all"
    return {"burst": burst, "served": len(ok), "shed": len(shed),
            "statuses": sorted(set(statuses)), "wall_s": wall}


# ---------------------------------------------------------------------------
# acceptance: silent-congestion drill -> estimator-drift alarm
# ---------------------------------------------------------------------------

def console_snapshot(gw) -> str:
    """``python -m repro.obs.console --once`` against the live gateway
    (real subprocess — the CI smoke path and the README screenshot)."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs.console", "--once",
         "--gateway", f"{gw.server.host}:{gw.port}"],
        capture_output=True, text=True, timeout=60, env=env)
    assert proc.returncode == 0, proc.stderr
    assert "fleet console" in proc.stdout, proc.stdout
    return proc.stdout


def congestion_drill(model, params, n: int = 16) -> dict:
    """Silently degrade live peers and watch the drift alarm fire.

    Phase 1 seeds every prompt's ranges into the fleet, then the
    warm gateway retires (its broker blob cache would satisfy phase-2
    refetches without touching the wire). ``set_throttle`` then paces
    each daemon's serving socket — no restart, nothing announced to
    clients — and a fresh gateway replays the same prompts: every
    resolve refetches over a degraded link, est-vs-actual error blows
    past the calibration band, the ``repro_estimator_drift`` gauge
    flips, and the flight recorder dumps an ``estimator_drift``
    snapshot."""
    from repro.obs import REGISTRY
    from repro.obs.flight import ESTIMATOR_DRIFT

    # several seeds => several distinct hot system prefixes: the broker
    # dedups each unique blob to ONE wire transfer, so one seed's worth
    # of traffic gives each peer too few est-vs-actual samples to clear
    # the calibration tracker's min_obs gate
    wls = [w for s in range(4)
           for w in MIXES["support"](max(n // 4, 2), seed=11 + s,
                                     rate_per_s=0.0, max_new_tokens=4)]

    def mk(fabric, name):
        return Gateway(model, params, fabric=fabric, batch_size=4,
                       max_len=MAX_LEN, max_inflight=64, queue_depth=64,
                       default_quota=TenantQuota(max_concurrent=64),
                       model_name=name).start()

    with Fabric.tcp(n_peers=2, cache_cfg=CacheConfig()) as fabric:
        gw = mk(fabric, "congestion-warm")
        try:
            results, _ = replay(gw, wls, time_scale=0.0)
            assert all(r.get("status") == 200 for r in results), results
            gw.engine.fetcher.flush_uploads()
            up = gw.engine.fetcher.stats
            blob_b = up["bytes_up"] / max(up["uploads"], 1)
        finally:
            gw.stop()
        # pace so one blob transfer takes ~0.5s beyond the pacer's
        # ~0.2s burst credit; planner estimates still assume the
        # unthrottled link, so actuals blow past them
        bps = max(blob_b * 8.0 / 0.7, 5e4)
        for pid in fabric.peer_ids():
            r = fabric.supervisor.set_throttle(pid, bps)
            assert r.get("ok"), r

        gw = mk(fabric, "congestion-drill")
        try:
            results, _ = replay(gw, wls, time_scale=0.0)
            assert all(r.get("status") == 200 for r in results), results
            cal = gw.engine.fetcher.directory.calibration
            drifted = cal.drifted()
            snap = cal.snapshot()
            _, flight = _get_json(gw, "/v1/flight")
            dumps = [d for d in flight["dumps"]
                     if d.get("reason") == ESTIMATOR_DRIFT]
            gauge = REGISTRY.snapshot().get("repro_estimator_drift", {})
            console = console_snapshot(gw)
            fstats = dict(gw.engine.fetcher.stats)
        finally:
            gw.stop()
    assert fstats["hits"] > 0, f"drill refetched nothing: {fstats}"
    assert drifted, f"throttled fleet flagged no drift: {snap}"
    assert isinstance(gauge, dict) and any(gauge.values()), \
        f"repro_estimator_drift gauge never flipped: {gauge}"
    assert dumps, "no estimator_drift flight dump"
    return {"throttle_bps": bps, "drifted_peers": drifted,
            "drift_gauge": gauge, "n_drift_dumps": len(dumps),
            "refetch_hits": fstats["hits"], "calibration": snap,
            "console_once": console}


# ---------------------------------------------------------------------------

def _pct(vals, q):
    return float(np.percentile(vals, q)) if vals else 0.0


def run_mix(gw, model, params, tok, name: str, n: int, rate: float,
            seed: int = 0) -> dict:
    wls = MIXES[name](n, seed=seed, rate_per_s=rate,
                      max_new_tokens=MAX_NEW)
    # warmup: one request per distinct prefill bucket, off the clock
    # (compile stalls would otherwise land in the first TTFTs)
    seen, warm = set(), []
    for wl in wls:
        b = len(protocol.tokenize_messages(tok, wl.messages).token_ids)
        b = 1 << (b - 1).bit_length()
        if b not in seen:
            seen.add(b)
            warm.append(wl)
    replay(gw, warm, time_scale=0.0)

    results, wall = replay(gw, wls)
    errors = [r for r in results if "error" in r or "tokens" not in r]
    assert not errors, f"{name}: replay failures: {errors[:3]}"

    ref = direct_tokens(model, params, tok, wls)
    for i, (r, expect) in enumerate(zip(results, ref)):
        assert r["tokens"] == list(expect), (
            f"{name}: request {i} diverged from the direct scheduler "
            f"run: gateway={r['tokens']} direct={list(expect)}")

    ledger = resolve_decisions(gw, results, name)

    ttfts = [r["ttft_s"] for r in results]
    ttlts = [r["ttlt_s"] for r in results]
    shed_n = sum(1 for r in results if r.get("status") in (429, 503))
    fleet = len(gw.engine.fabric.peer_ids()) + 1    # peers + gateway box
    fstats = dict(gw.engine.fetcher.stats)
    gb = (fstats["bytes_down"] + fstats["bytes_up"]) / 1e9
    cost_1k = (wall / 3600 * fleet * DEVICE_USD_PER_HR
               + gb * EGRESS_USD_PER_GB) / max(n, 1) * 1000
    return {
        "n_requests": n, "wall_s": wall,
        "ttft_p50_s": _pct(ttfts, 50), "ttft_p95_s": _pct(ttfts, 95),
        "ttlt_p50_s": _pct(ttlts, 50), "ttlt_p95_s": _pct(ttlts, 95),
        "shed_rate": shed_n / max(n, 1),
        "cost_per_1k_usd": cost_1k,
        "cache": fstats,
        "ledger": ledger,
        "token_identity": "ok",
    }


def main(quick: bool = False, only_mix: str = ""):
    cfg = get_config("gemma3-270m").reduced()
    model = Model(cfg)
    import jax
    params = model.init(jax.random.PRNGKey(0))
    tok = WordHashTokenizer(cfg.vocab)

    n = 6 if quick else 16
    rate = 12.0
    report = {"config": {"model": cfg.name, "max_len": MAX_LEN,
                         "max_new": MAX_NEW, "n_per_mix": n,
                         "rate_per_s": rate}, "mixes": {}}
    lines = []
    spans: dict = {}
    last_spans: list = []
    mixes = [only_mix] if only_mix else list(MIXES)
    for name in mixes:
        # fresh fleet per mix so cache stats and cost are per-mix
        with Fabric.tcp(n_peers=2, cache_cfg=CacheConfig()) as fabric:
            gw = Gateway(model, params, fabric=fabric, batch_size=4,
                         max_len=MAX_LEN, max_inflight=64,
                         queue_depth=64,
                         default_quota=TenantQuota(max_concurrent=64),
                         model_name=f"gateway-{name}").start()
            try:
                res = run_mix(gw, model, params, tok, name, n, rate)
            finally:
                # each mix owns a short-lived gateway; fold its span
                # rollup into the report before the tracer goes away
                merge_rollups(spans, gw.tracer.rollup())
                last_spans = gw.tracer.spans()
                gw.stop()
        report["mixes"][name] = res
        lines.append(csv_line(
            f"gateway_{name}", res["ttft_p50_s"] * 1e6,
            f"ttft_p95_ms={res['ttft_p95_s'] * 1e3:.1f};"
            f"ttlt_p95_ms={res['ttlt_p95_s'] * 1e3:.1f};"
            f"shed_rate={res['shed_rate']:.2f};"
            f"hits={res['cache']['hits']}/{res['cache']['resolves']};"
            f"regret_s={res['ledger']['regret_s']:.3f};"
            f"cost_1k=${res['cost_per_1k_usd']:.4f}"))

    report["shed_drill"] = shed_drill(model, params)
    lines.append(csv_line(
        "gateway_shed_drill", report["shed_drill"]["wall_s"] * 1e6,
        f"served={report['shed_drill']['served']};"
        f"shed={report['shed_drill']['shed']};"
        f"statuses={report['shed_drill']['statuses']}"))

    report["congestion_drill"] = congestion_drill(model, params)
    lines.append(csv_line(
        "gateway_congestion_drill",
        report["congestion_drill"]["throttle_bps"],
        f"drifted={report['congestion_drill']['drifted_peers']};"
        f"dumps={report['congestion_drill']['n_drift_dumps']}"))

    # whole-run ledger accounting + CI artifact spills: the full
    # decision ledger as JSONL, and the last mix's span tree as a
    # Perfetto-loadable trace
    from repro.obs import LEDGER
    from repro.obs.export import write_perfetto
    report["ledger_totals"] = LEDGER.totals()
    LEDGER.dump_jsonl("BENCH_gateway_load_ledger.jsonl")
    if last_spans:
        write_perfetto("BENCH_gateway_load_trace.json", last_spans,
                       default_proc="gateway")

    write_bench("BENCH_gateway_load.json", report, spans=spans)
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--mix", default="", choices=["", *MIXES],
                    help="run a single mix")
    args = ap.parse_args()
    main(quick=args.quick, only_mix=args.mix)
