"""Gateway load replay: three production mixes over a real TCP fabric.

End-to-end through every layer this repo has: seeded workload
generators (``repro.workloads``) -> HTTP/SSE against the OpenAI-style
gateway -> continuous-batching scheduler -> blocking prompt-cache
resolve/upload against a ``Fabric.tcp`` fleet of real
``PeerSupervisor`` daemon processes.

Per mix it reports client-observed TTFT/TTLT p50/p95, shed rate,
cache traffic, and a nominal cost-per-1K-requests (device-hours +
egress). Two acceptance checks run inline:

* **token identity** — every gateway completion must match a direct
  in-process ``Scheduler`` run of the same prompt (greedy, same
  model/params/max_len), cache hits included;
* **bounded shedding** — a burst against a 1-slot gateway must shed
  with 429/503 + ``Retry-After`` instead of queueing unboundedly.

Emits ``BENCH_gateway_load.json``. Usage::

    PYTHONPATH=src python -m benchmarks.gateway_load [--quick] [--mix m]
"""
from __future__ import annotations

import argparse
import http.client
import json
import threading
import time

import numpy as np

from benchmarks.common import csv_line, merge_rollups, write_bench
from repro.config import CacheConfig
from repro.configs import get_config
from repro.core import Fabric
from repro.data import WordHashTokenizer
from repro.gateway import Gateway, TenantQuota, protocol
from repro.models import Model
from repro.serving import BatchedEngine, Request, Scheduler
from repro.workloads import MIXES

MAX_LEN = 384
MAX_NEW = 8
# nominal fleet economics: edge device $/hr per box, LAN egress $/GB
DEVICE_USD_PER_HR = 0.12
EGRESS_USD_PER_GB = 0.02


# ---------------------------------------------------------------------------
# HTTP replay client (stdlib only; SSE readline gives client-side TTFT)
# ---------------------------------------------------------------------------

def _stream_one(host: str, port: int, wl, out: dict) -> None:
    t0 = time.perf_counter()
    try:
        conn = http.client.HTTPConnection(host, port, timeout=120)
        conn.request("POST", "/v1/chat/completions",
                     json.dumps(wl.body(stream=True)),
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        out["status"] = resp.status
        if resp.status != 200:
            out["retry_after"] = resp.getheader("Retry-After")
            resp.read()
            return
        tokens = []
        while True:
            line = resp.readline()
            if not line:
                break
            if not line.startswith(b"data: "):
                continue
            payload = line[6:].strip()
            if payload == b"[DONE]":
                break
            chunk = json.loads(payload)
            choice = chunk["choices"][0]
            if "token_id" in choice:
                if not tokens:
                    out["ttft_s"] = time.perf_counter() - t0
                tokens.append(choice["token_id"])
        out["ttlt_s"] = time.perf_counter() - t0
        out["tokens"] = tokens
    except Exception as e:            # noqa: BLE001 — record, don't hang
        out["error"] = repr(e)
    finally:
        try:
            conn.close()
        except Exception:
            pass


def replay(gw, requests, time_scale: float = 1.0):
    """Fire each request at its (scaled) arrival offset, concurrently."""
    results = [dict() for _ in requests]
    t0 = time.perf_counter()

    def worker(i, wl):
        delay = wl.arrival_s * time_scale - (time.perf_counter() - t0)
        if delay > 0:
            time.sleep(delay)
        _stream_one(gw.server.host, gw.port, wl, results[i])

    threads = [threading.Thread(target=worker, args=(i, wl), daemon=True)
               for i, wl in enumerate(requests)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(180)
    wall = time.perf_counter() - t0
    return results, wall


# ---------------------------------------------------------------------------
# acceptance: token identity vs a direct in-process scheduler run
# ---------------------------------------------------------------------------

def direct_tokens(model, params, tok, requests):
    """Greedy reference completions, no gateway, no cache."""
    eng = BatchedEngine(model, params, max_len=MAX_LEN, batch_size=2)
    sched = Scheduler(eng)
    reqs = []
    for wl in requests:
        segs = protocol.tokenize_messages(tok, wl.messages)
        reqs.append(Request(tokens=np.asarray(segs.token_ids, np.int32),
                            max_new_tokens=wl.max_new_tokens))
    sched.run(reqs)
    return [r.stats.output_tokens for r in reqs]


# ---------------------------------------------------------------------------
# acceptance: bounded shedding under slot exhaustion
# ---------------------------------------------------------------------------

def shed_drill(model, params, burst: int = 6) -> dict:
    """Burst a 1-slot gateway (queue_depth=1): extras must shed with
    429/503 + Retry-After, never queue unboundedly."""
    gw = Gateway(model, params, fabric=None, batch_size=1,
                 max_len=MAX_LEN, max_inflight=1, queue_depth=1,
                 default_quota=TenantQuota(max_concurrent=burst),
                 model_name="shed-drill").start()
    try:
        wls = MIXES["support"](burst, seed=7, rate_per_s=0.0,
                               max_new_tokens=48)
        results, wall = replay(gw, wls)
    finally:
        gw.stop()
    statuses = [r.get("status") for r in results]
    shed = [r for r in results if r.get("status") in (429, 503)]
    ok = [r for r in results if r.get("status") == 200]
    assert not any("error" in r for r in results), \
        f"shed drill had transport errors: {results}"
    assert all(s in (200, 429, 503) for s in statuses), \
        f"unexpected statuses under overload: {statuses}"
    assert shed, "slot exhaustion did not shed any requests"
    assert all(r.get("retry_after") for r in shed), \
        "shed responses missing Retry-After"
    assert ok, "overloaded gateway served nothing at all"
    return {"burst": burst, "served": len(ok), "shed": len(shed),
            "statuses": sorted(set(statuses)), "wall_s": wall}


# ---------------------------------------------------------------------------

def _pct(vals, q):
    return float(np.percentile(vals, q)) if vals else 0.0


def run_mix(gw, model, params, tok, name: str, n: int, rate: float,
            seed: int = 0) -> dict:
    wls = MIXES[name](n, seed=seed, rate_per_s=rate,
                      max_new_tokens=MAX_NEW)
    # warmup: one request per distinct prefill bucket, off the clock
    # (compile stalls would otherwise land in the first TTFTs)
    seen, warm = set(), []
    for wl in wls:
        b = len(protocol.tokenize_messages(tok, wl.messages).token_ids)
        b = 1 << (b - 1).bit_length()
        if b not in seen:
            seen.add(b)
            warm.append(wl)
    replay(gw, warm, time_scale=0.0)

    results, wall = replay(gw, wls)
    errors = [r for r in results if "error" in r or "tokens" not in r]
    assert not errors, f"{name}: replay failures: {errors[:3]}"

    ref = direct_tokens(model, params, tok, wls)
    for i, (r, expect) in enumerate(zip(results, ref)):
        assert r["tokens"] == list(expect), (
            f"{name}: request {i} diverged from the direct scheduler "
            f"run: gateway={r['tokens']} direct={list(expect)}")

    ttfts = [r["ttft_s"] for r in results]
    ttlts = [r["ttlt_s"] for r in results]
    shed_n = sum(1 for r in results if r.get("status") in (429, 503))
    fleet = len(gw.engine.fabric.peer_ids()) + 1    # peers + gateway box
    fstats = dict(gw.engine.fetcher.stats)
    gb = (fstats["bytes_down"] + fstats["bytes_up"]) / 1e9
    cost_1k = (wall / 3600 * fleet * DEVICE_USD_PER_HR
               + gb * EGRESS_USD_PER_GB) / max(n, 1) * 1000
    return {
        "n_requests": n, "wall_s": wall,
        "ttft_p50_s": _pct(ttfts, 50), "ttft_p95_s": _pct(ttfts, 95),
        "ttlt_p50_s": _pct(ttlts, 50), "ttlt_p95_s": _pct(ttlts, 95),
        "shed_rate": shed_n / max(n, 1),
        "cost_per_1k_usd": cost_1k,
        "cache": fstats,
        "token_identity": "ok",
    }


def main(quick: bool = False, only_mix: str = ""):
    cfg = get_config("gemma3-270m").reduced()
    model = Model(cfg)
    import jax
    params = model.init(jax.random.PRNGKey(0))
    tok = WordHashTokenizer(cfg.vocab)

    n = 6 if quick else 16
    rate = 12.0
    report = {"config": {"model": cfg.name, "max_len": MAX_LEN,
                         "max_new": MAX_NEW, "n_per_mix": n,
                         "rate_per_s": rate}, "mixes": {}}
    lines = []
    spans: dict = {}
    mixes = [only_mix] if only_mix else list(MIXES)
    for name in mixes:
        # fresh fleet per mix so cache stats and cost are per-mix
        with Fabric.tcp(n_peers=2, cache_cfg=CacheConfig()) as fabric:
            gw = Gateway(model, params, fabric=fabric, batch_size=4,
                         max_len=MAX_LEN, max_inflight=64,
                         queue_depth=64,
                         default_quota=TenantQuota(max_concurrent=64),
                         model_name=f"gateway-{name}").start()
            try:
                res = run_mix(gw, model, params, tok, name, n, rate)
            finally:
                # each mix owns a short-lived gateway; fold its span
                # rollup into the report before the tracer goes away
                merge_rollups(spans, gw.tracer.rollup())
                gw.stop()
        report["mixes"][name] = res
        lines.append(csv_line(
            f"gateway_{name}", res["ttft_p50_s"] * 1e6,
            f"ttft_p95_ms={res['ttft_p95_s'] * 1e3:.1f};"
            f"ttlt_p95_ms={res['ttlt_p95_s'] * 1e3:.1f};"
            f"shed_rate={res['shed_rate']:.2f};"
            f"hits={res['cache']['hits']}/{res['cache']['resolves']};"
            f"cost_1k=${res['cost_per_1k_usd']:.4f}"))

    report["shed_drill"] = shed_drill(model, params)
    lines.append(csv_line(
        "gateway_shed_drill", report["shed_drill"]["wall_s"] * 1e6,
        f"served={report['shed_drill']['served']};"
        f"shed={report['shed_drill']['shed']};"
        f"statuses={report['shed_drill']['statuses']}"))

    write_bench("BENCH_gateway_load.json", report, spans=spans)
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--mix", default="", choices=["", *MIXES],
                    help="run a single mix")
    args = ap.parse_args()
    main(quick=args.quick, only_mix=args.mix)
