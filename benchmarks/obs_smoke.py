"""Observability smoke: tracing must be near-free and the telemetry real.

Replays a short customer-support mix through the HTTP gateway twice —
once with the default tracer, once with ``NULL_TRACER`` — and asserts:

* **telemetry is real**: after the traced replay, a real HTTP scrape of
  ``GET /metrics`` contains the key Prometheus series
  (``gateway_ttft_seconds_bucket``, ``gateway_http_requests_total``,
  ``sched_requests_total``, ``gateway_request_seconds``) and the last
  request's ``cache.trace_id`` resolves via ``GET /v1/traces/<id>`` to
  a span tree containing ``gw.request`` and the slot lifecycle;
* **tracing is near-free**: client-observed p50 TTFT with tracing on
  regresses < 2% vs tracing off (plus a small absolute epsilon — these
  are millisecond-scale reduced-model requests). Best-of-``ATTEMPTS``
  replays on the same warmed gateways, so one noisy run on a shared CI
  box doesn't fail the job.

Emits ``BENCH_obs_smoke.json`` (p50s, overhead fraction, series seen,
span rollup). Usage::

    PYTHONPATH=src python -m benchmarks.obs_smoke [--quick]
"""
from __future__ import annotations

import http.client
import json
import sys
import time

from benchmarks.common import csv_line, write_bench
from repro.configs import get_config
from repro.gateway import Gateway, TenantQuota
from repro.models import Model
from repro.obs.trace import NULL_TRACER
from repro.workloads import MIXES

MAX_LEN = 384
KEY_SERIES = (
    "# TYPE gateway_ttft_seconds histogram",
    "gateway_ttft_seconds_bucket",
    "gateway_http_requests_total",
    "gateway_request_seconds_count",
    "sched_requests_total",
    "sched_queue_wait_seconds_bucket",
)
ATTEMPTS = 3          # best-of replays for the overhead comparison
EPS_S = 2e-3          # absolute slack on top of the 2% bound


def _stream_ttft(host: str, port: int, wl) -> float:
    """One SSE request; returns client-observed TTFT seconds."""
    t0 = time.perf_counter()
    conn = http.client.HTTPConnection(host, port, timeout=120)
    try:
        conn.request("POST", "/v1/chat/completions",
                     json.dumps(wl.body(stream=True)),
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        assert resp.status == 200, resp.status
        ttft = None
        while True:
            line = resp.readline()
            if not line:
                break
            if ttft is None and line.startswith(b"data:") \
                    and b"[DONE]" not in line:
                ttft = time.perf_counter() - t0
        assert ttft is not None, "stream produced no tokens"
        return ttft
    finally:
        conn.close()


def _unary(host: str, port: int, wl) -> dict:
    conn = http.client.HTTPConnection(host, port, timeout=120)
    try:
        conn.request("POST", "/v1/chat/completions",
                     json.dumps(wl.body()),
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        assert resp.status == 200, resp.status
        return json.loads(resp.read())
    finally:
        conn.close()


def _get(host: str, port: int, path: str):
    conn = http.client.HTTPConnection(host, port, timeout=30)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return resp.status, resp.read()
    finally:
        conn.close()


def _p50(xs):
    xs = sorted(xs)
    return xs[len(xs) // 2]


def replay_p50(gw: Gateway, reqs) -> float:
    return _p50([_stream_ttft(gw.server.host, gw.port, wl)
                 for wl in reqs])


def check_telemetry(gw: Gateway, wl, out: dict) -> None:
    """Scrape /metrics over real HTTP + resolve one request's trace."""
    status, body = _get(gw.server.host, gw.port, "/metrics")
    assert status == 200, status
    text = body.decode()
    missing = [s for s in KEY_SERIES if s not in text]
    assert not missing, f"missing Prometheus series: {missing}"
    out["metrics_series_ok"] = list(KEY_SERIES)

    resp = _unary(gw.server.host, gw.port, wl)
    tid = resp.get("cache", {}).get("trace_id", "")
    assert tid, f"unary response carried no trace_id: {resp.get('cache')}"
    status, body = _get(gw.server.host, gw.port, f"/v1/traces/{tid}")
    assert status == 200, status
    tree = json.loads(body)
    names = {s["name"] for s in tree["spans"]}
    need = {"gw.request", "gw.parse", "slot.prefill", "slot.decode"}
    assert need <= names, f"trace missing spans: {need - names}"
    out["trace_resolved"] = {"trace_id": tid, "n_spans": tree["n_spans"]}


def main():
    quick = "--quick" in sys.argv
    n = 8 if quick else 24
    warm = 2 if quick else 4

    cfg = get_config("gemma3-270m").reduced()
    model = Model(cfg)
    import jax
    params = model.init(jax.random.PRNGKey(0))
    reqs = MIXES["support"](n + warm + 1, seed=0, rate_per_s=0.0,
                            max_new_tokens=4)

    def mk(tracer=None):
        return Gateway(model, params, batch_size=2, max_len=MAX_LEN,
                       max_inflight=8, queue_depth=8,
                       default_quota=TenantQuota(max_concurrent=8),
                       model_name="obs-smoke", tracer=tracer).start()

    lines, out = [], {"n_per_replay": n}
    gw_on, gw_off = mk(), mk(tracer=NULL_TRACER)
    try:
        for gw in (gw_on, gw_off):                       # JIT warmup
            replay_p50(gw, reqs[:warm])
        check_telemetry(gw_on, reqs[warm], out)

        # all ATTEMPTS trials run (no early break): the bound applies
        # to the min, but every trial lands in the BENCH json so a
        # noisy CI box is visible in the artifact, not hidden by the
        # first lucky pair
        trials_on, trials_off = [], []
        for _ in range(ATTEMPTS):
            trials_off.append(replay_p50(gw_off, reqs[warm + 1:]))
            trials_on.append(replay_p50(gw_on, reqs[warm + 1:]))
        best_on, best_off = min(trials_on), min(trials_off)
        overhead = best_on / best_off - 1.0
        out.update(ttft_p50_on_s=best_on, ttft_p50_off_s=best_off,
                   trials_on_s=trials_on, trials_off_s=trials_off,
                   overhead_frac=overhead)
        assert best_on <= best_off * 1.02 + EPS_S, (
            f"tracing overhead {overhead:+.1%} exceeds 2% "
            f"(on={best_on * 1e3:.2f}ms off={best_off * 1e3:.2f}ms)")
        out["overhead_ok"] = True
        lines.append(csv_line(
            "obs_smoke", best_on * 1e6,
            f"overhead={overhead:+.1%};"
            f"series={len(KEY_SERIES)}ok;"
            f"trace_spans={out['trace_resolved']['n_spans']}"))
        spans = gw_on.tracer.rollup()
    finally:
        gw_on.stop()
        gw_off.stop()

    write_bench("BENCH_obs_smoke.json", out, spans=spans)
    return lines


if __name__ == "__main__":
    main()
