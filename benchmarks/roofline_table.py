"""§Roofline: three-term roofline per (arch x shape) from the dry-run
records (experiments/dryrun_single.jsonl). Uses depth-extrapolated
FLOPs/bytes/collectives when probes are present, else raw; adds
MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference) and the
useful-compute ratio."""
from __future__ import annotations

import json
import os

from benchmarks.common import csv_line
from repro.config import SHAPES
from repro.configs import get_config
from repro.roofline.analysis import roofline_terms
from repro.roofline.hw import V5E

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun_single.jsonl")


def model_flops(cfg, shape) -> float:
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    per_tok = 6 * n if shape.kind == "train" else 2 * n
    return per_tok * tokens


def load_records(path=DRYRUN):
    recs = {}
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("ok"):
                recs[(r["arch"], r["shape"])] = r
    return recs


def terms_for(rec):
    flops = rec.get("ext_flops", rec.get("raw_flops", 0.0))
    bytes_ = rec.get("ext_bytes", rec.get("raw_bytes", 0.0))
    coll = rec.get("ext_coll_bytes", rec.get("raw_coll_bytes", 0.0))
    return roofline_terms(flops, bytes_, coll, rec["chips"], V5E), \
        flops, bytes_, coll


def main():
    recs = load_records()
    lines = []
    for (arch, shape_name), rec in sorted(recs.items()):
        shape = SHAPES[shape_name]
        cfg = get_config(arch)
        terms, flops, bytes_, coll = terms_for(rec)
        mf = model_flops(cfg, shape) / rec["chips"]   # per-chip
        useful = mf / flops if flops else 0.0
        step = max(terms["compute_s"], terms["memory_s"],
                   terms["collective_s"])
        lines.append(csv_line(
            f"roofline_{arch}_{shape_name}", step * 1e6,
            f"compute={terms['compute_s']:.2e}s;"
            f"memory={terms['memory_s']:.2e}s;"
            f"collective={terms['collective_s']:.2e}s;"
            f"dominant={terms['dominant']};"
            f"useful_flops_ratio={useful:.2f};"
            f"fits_hbm={rec.get('fits_hbm')}"))
    if not lines:
        lines.append(csv_line("roofline_table", 0,
                              "no dryrun records; run launch.dryrun first"))
    return lines


if __name__ == "__main__":
    main()
