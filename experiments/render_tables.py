"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the sweep
records. Usage: PYTHONPATH=src python experiments/render_tables.py"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.config import SHAPES  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.roofline.analysis import roofline_terms  # noqa: E402
from repro.roofline.hw import V5E  # noqa: E402

HERE = os.path.dirname(__file__)


def load(path):
    out = {}
    with open(os.path.join(HERE, path)) as f:
        for line in f:
            r = json.loads(line)
            if r.get("ok"):
                out[(r["arch"], r["shape"])] = r
    return out


def gib(n):
    return f"{(n or 0) / 2**30:.2f}"


def model_flops(cfg, shape, chips):
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    per = 6 * n if shape.kind == "train" else 2 * n
    return per * tokens / chips


def main():
    single = load("dryrun_single.jsonl")
    multi = load("dryrun_multi.jsonl")

    print("### §Dry-run — per-device memory (single-pod 16x16 / "
          "multi-pod 2x16x16)\n")
    print("| arch | shape | layout | compile s | args GiB (1pod/2pod) | "
          "temp GiB (1pod/2pod) | fits v5e (1pod/2pod) |")
    print("|---|---|---|---|---|---|---|")
    for (arch, shape), r in sorted(single.items()):
        m = multi.get((arch, shape), {})
        print(f"| {arch} | {shape} | {r.get('layout', 'tp')} "
              f"| {r['compile_s']} "
              f"| {gib(r['argument_size_in_bytes'])}/"
              f"{gib(m.get('argument_size_in_bytes'))} "
              f"| {gib(r['temp_size_in_bytes'])}/"
              f"{gib(m.get('temp_size_in_bytes'))} "
              f"| {r['fits_hbm']}/{m.get('fits_hbm')} |")

    print("\n### §Roofline — depth-extrapolated terms, single-pod "
          "(256 chips)\n")
    print("| arch | shape | compute s | memory s | collective s | "
          "dominant | MODEL_FLOPS/HLO |")
    print("|---|---|---|---|---|---|---|")
    for (arch, shape), r in sorted(single.items()):
        fl = r.get("ext_flops", r["raw_flops"])
        by = r.get("ext_bytes", r["raw_bytes"])
        co = r.get("ext_coll_bytes", r["raw_coll_bytes"])
        t = roofline_terms(fl, by, co, r["chips"], V5E)
        mf = model_flops(get_config(arch), SHAPES[shape], r["chips"])
        print(f"| {arch} | {shape} | {t['compute_s']:.2e} "
              f"| {t['memory_s']:.2e} | {t['collective_s']:.2e} "
              f"| {t['dominant']} | {min(mf / fl, 9.99):.2f} |")


if __name__ == "__main__":
    main()
